//! The Erda client: the protocol state machine of §3.3/§4.2/§4.3.
//!
//! Normal mode:
//! * **Read** = two one-sided RDMA reads (hash-entry neighborhood, then the
//!   object) + local checksum verification. A torn object triggers the
//!   consistency path: count the inconsistency, notify the server (repair),
//!   and fall back to the old offset from the already-fetched entry — or
//!   retry after a short delay when no old version exists yet (§4.3).
//! * **Write/Delete** = write_with_imm metadata request (server CPU updates
//!   the entry and returns the reserved log address) followed by a
//!   one-sided data write straight to the log region — zero copy, no
//!   buffering, no second NVM write.
//!
//! While the key's head is under log cleaning, ops go through two-sided
//! sends served by the server CPU (§4.4) — that is what Fig 26 measures.
//!
//! Failure injection: a scripted [`Request::CrashDuringPut`] posts only a
//! prefix of the object's chunks and kills the client, leaving a torn
//! object for other clients (and recovery) to detect.
//!
//! The per-op state machine is factored into [`begin_op`]/[`advance_op`]
//! (crate-internal), consumed by two actors: the closed-loop [`ErdaClient`]
//! here (one op in flight — the paper's client model) and the windowed
//! cluster-level [`crate::store::pipeline::PipelinedClient`], which keeps
//! several of these state machines in flight at once — each bound to the
//! shard world its key routes to, so one client's window spans shards in
//! the co-simulated cluster. Both drivers mutate only the world they are
//! handed, which is what lets the same `begin`/`advance` code run under a
//! single-world engine or inside [`crate::store::cosim::ClusterState`].
//!
//! That world-parametricity is also what makes synchronous mirroring
//! ([`crate::store::mirror`]) a pure composition: the windowed client adds
//! an extra in-flight leg per put/delete by replaying this very state
//! machine — [`begin_op`] with the same request — against the shard's
//! MIRROR world once the primary leg persists, so the mirror pays the full
//! protocol (write_with_imm metadata update at the mirror server + the
//! one-sided data write, checksum-gated on the mirror's log) and the op
//! ACKs only after both replicas persisted.

use super::server::ErdaWorld;
use crate::log::{object, HeadId, LogOffset, NO_OFFSET};
use crate::sim::{Actor, Step, Time};
use crate::store::pipeline::OpOutcome;
use crate::store::{OpSource, Request};

/// Client tunables.
#[derive(Clone, Copy, Debug)]
pub struct ClientConfig {
    /// Largest value the run can return (sizes the object read window).
    pub max_value: usize,
    /// Back-off before re-reading when no old version exists yet (§4.3).
    pub retry_delay: Time,
    /// Bounded retries before giving up a read.
    pub max_retries: u32,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig { max_value: 4096, retry_delay: 10_000, max_retries: 8 }
    }
}

/// Per-op protocol state. `start` is the op's latency clock origin: issue
/// time for closed-loop ops, arrival time for open-loop ops (queueing
/// counts).
pub(crate) enum St {
    NextOp,
    /// Waiting for the entry-neighborhood RDMA read to complete.
    EntryRead { key: Vec<u8>, retries: u32, start: Time, cleaning: bool },
    /// Waiting for the object RDMA read to complete.
    ObjectRead {
        key: Vec<u8>,
        head: HeadId,
        off: LogOffset,
        fallback: Option<LogOffset>,
        retries: u32,
        start: Time,
        window: usize,
        cleaning: bool,
    },
    /// Backing off before retrying the read from the entry.
    RetryWait { key: Vec<u8>, retries: u32, start: Time, cleaning: bool },
    /// Two-sided read during log cleaning; resolves at completion.
    CleanRead { key: Vec<u8>, start: Time },
    /// Two-sided write during log cleaning; applies at completion.
    CleanWrite { key: Vec<u8>, value: Vec<u8>, deleted: bool, start: Time },
    /// Waiting for the write_with_imm metadata reply.
    WriteReply { key: Vec<u8>, obj: Vec<u8>, start: Time, crash_chunks: Option<usize> },
    /// Waiting for the one-sided data write ACK.
    WriteAck { start: Time, cleaning: bool },
    Dead,
}

/// Issue the entry-neighborhood read (first hop of the read path).
fn issue_entry_read(
    w: &mut ErdaWorld,
    key: Vec<u8>,
    retries: u32,
    start: Time,
    now: Time,
    cleaning: bool,
) -> OpOutcome<St> {
    let (_, len) = w.server.neighborhood_addr(&key);
    let done = w.fabric.read_done(now, len);
    OpOutcome::Continue(St::EntryRead { key, retries, start, cleaning }, done)
}

/// Issue the object read at `(head, off)`.
#[allow(clippy::too_many_arguments)]
fn issue_object_read(
    cfg: &ClientConfig,
    w: &mut ErdaWorld,
    key: Vec<u8>,
    head: HeadId,
    off: LogOffset,
    fallback: Option<LogOffset>,
    retries: u32,
    start: Time,
    now: Time,
    cleaning: bool,
) -> OpOutcome<St> {
    let window = object::wire_size(key.len(), cfg.max_value).min(w.server.log.window(off));
    let done = w.fabric.read_done(now, window);
    OpOutcome::Continue(
        St::ObjectRead { key, head, off, fallback, retries, start, window, cleaning },
        done,
    )
}

/// Write path step 1: write_with_imm metadata request (§3.3).
fn issue_write_request(
    w: &mut ErdaWorld,
    key: Vec<u8>,
    obj: Vec<u8>,
    start: Time,
    now: Time,
    crash_chunks: Option<usize>,
) -> OpOutcome<St> {
    let t = &w.fabric.timing;
    let req = key.len() + 16; // key + length + imm identifier
    let svc = t.cpu_erda_write;
    let arrival = w.fabric.one_way(now, req);
    let resv = w.cpu.reserve(arrival, svc);
    let done = resv.end + w.fabric.timing.two_sided_rtt / 2;
    w.fabric.note_two_sided(req, 16);
    OpOutcome::Continue(St::WriteReply { key, obj, start, crash_chunks }, done)
}

/// Start one operation: post its first verb(s) at `now`; the op's latency
/// clock runs from `start` (== `now` for closed-loop clients).
pub(crate) fn begin_op(
    cfg: &ClientConfig,
    w: &mut ErdaWorld,
    op: Request,
    start: Time,
    now: Time,
) -> OpOutcome<St> {
    let t = &w.fabric.timing;
    match op {
        Request::Get { key } => {
            let h = super::head_of(&key, w.server.num_heads());
            if w.server.is_cleaning(h) {
                // §4.4: two-sided send path during cleaning.
                let svc = t.cpu_request_fixed
                    + t.cpu_log_search
                    + t.cpu_hash_op
                    + t.cpu_bytes(cfg.max_value);
                let arrival = w.fabric.one_way(now, key.len() + 16);
                let resv = w.cpu.reserve(arrival, svc);
                let resp_wire =
                    w.fabric.timing.wire(object::wire_size(key.len(), cfg.max_value));
                let done = resv.end + (w.fabric.timing.two_sided_rtt / 2) + resp_wire;
                w.fabric.note_two_sided(key.len() + 16, cfg.max_value);
                OpOutcome::Continue(St::CleanRead { key, start }, done)
            } else {
                issue_entry_read(w, key, 0, start, now, false)
            }
        }
        Request::Put { key, value } => {
            let h = super::head_of(&key, w.server.num_heads());
            if w.server.is_cleaning(h) {
                let svc = t.cpu_request_fixed
                    + t.cpu_baseline_write
                    + t.cpu_hash_op
                    + t.cpu_bytes(value.len())
                    + t.nvm_write(object::wire_size(key.len(), value.len()));
                let arrival = w.fabric.one_way(now, object::wire_size(key.len(), value.len()));
                let resv = w.cpu.reserve(arrival, svc);
                let done = resv.end + w.fabric.timing.two_sided_rtt / 2;
                w.fabric.note_two_sided(object::wire_size(key.len(), value.len()), 16);
                OpOutcome::Continue(St::CleanWrite { key, value, deleted: false, start }, done)
            } else {
                let obj = object::encode_object(&key, &value);
                issue_write_request(w, key, obj, start, now, None)
            }
        }
        Request::Delete { key } => {
            let h = super::head_of(&key, w.server.num_heads());
            if w.server.is_cleaning(h) {
                let svc = t.cpu_request_fixed + t.cpu_baseline_write + t.cpu_hash_op;
                let arrival = w.fabric.one_way(now, key.len() + 16);
                let resv = w.cpu.reserve(arrival, svc);
                let done = resv.end + w.fabric.timing.two_sided_rtt / 2;
                w.fabric.note_two_sided(key.len() + 16, 16);
                let st = St::CleanWrite { key, value: Vec::new(), deleted: true, start };
                OpOutcome::Continue(st, done)
            } else {
                let obj = object::encode_delete(&key);
                issue_write_request(w, key, obj, start, now, None)
            }
        }
        Request::CrashDuringPut { key, value, chunks } => {
            let obj = object::encode_object(&key, &value);
            issue_write_request(w, key, obj, start, now, Some(chunks))
        }
    }
}

/// Advance an in-flight op whose pending verb completed at `now`.
pub(crate) fn advance_op(
    cfg: &ClientConfig,
    w: &mut ErdaWorld,
    st: St,
    now: Time,
) -> OpOutcome<St> {
    match st {
        St::NextOp | St::Dead => unreachable!("not an in-flight op state"),

        St::EntryRead { key, retries, start, cleaning } => {
            let (addr, len) = w.server.neighborhood_addr(&key);
            let bytes = {
                let ErdaWorld { nvm, fabric, .. } = w;
                fabric.sample(now, nvm, addr, len)
            };
            match super::server::ErdaServer::parse_neighborhood(&bytes, &key) {
                None => {
                    w.counters.read_misses += 1;
                    OpOutcome::Finished { start, cleaning }
                }
                Some(e) => {
                    let newest = e.atomic.newest();
                    if newest == NO_OFFSET {
                        w.counters.read_misses += 1;
                        return OpOutcome::Finished { start, cleaning };
                    }
                    let fb = match e.atomic.oldest() {
                        NO_OFFSET => None,
                        o => Some(o),
                    };
                    issue_object_read(
                        cfg, w, key, e.head_id, newest, fb, retries, start, now, cleaning,
                    )
                }
            }
        }

        St::ObjectRead { key, head, off, fallback, retries, start, window, cleaning } => {
            let addr = w.server.log.addr_of(head, off);
            let bytes = {
                let ErdaWorld { nvm, fabric, .. } = w;
                fabric.sample(now, nvm, addr, window)
            };
            match object::decode(&bytes) {
                Ok(v) if v.deleted => {
                    // A valid delete record: key is absent.
                    w.counters.read_misses += 1;
                    OpOutcome::Finished { start, cleaning }
                }
                Ok(_) => OpOutcome::Finished { start, cleaning },
                Err(_) => {
                    // Torn or not-yet-written object detected by checksum
                    // — the §4.2 consistency path.
                    w.counters.inconsistencies += 1;
                    if let Some(old) = fallback {
                        w.counters.fallbacks += 1;
                        // Notify the server (repair message; small send).
                        let t = &w.fabric.timing;
                        let svc = t.cpu_request_fixed + t.cpu_hash_op;
                        let arrival = w.fabric.one_way(now, key.len() + 16);
                        w.cpu.reserve(arrival, svc);
                        // The repair is served one way later; chunks that
                        // persist in between must be visible to its
                        // still-torn re-check (§4.3 race guard).
                        {
                            let ErdaWorld { nvm, fabric, .. } = w;
                            fabric.flush(arrival, nvm);
                        }
                        if w.server.repair(&mut w.nvm, &key, off) {
                            w.counters.repairs += 1;
                        }
                        issue_object_read(
                            cfg, w, key, head, old, None, retries, start, now, cleaning,
                        )
                    } else if retries < cfg.max_retries {
                        w.counters.retries += 1;
                        OpOutcome::Continue(
                            St::RetryWait { key, retries: retries + 1, start, cleaning },
                            now + cfg.retry_delay,
                        )
                    } else {
                        w.counters.read_misses += 1;
                        OpOutcome::Finished { start, cleaning }
                    }
                }
            }
        }

        St::RetryWait { key, retries, start, cleaning } => {
            issue_entry_read(w, key, retries, start, now, cleaning)
        }

        St::CleanRead { key, start } => {
            // Server resolved the read at service time; data returned now.
            let _ = w.server.local_read(&w.nvm, &key);
            OpOutcome::Finished { start, cleaning: true }
        }

        St::CleanWrite { key, value, deleted, start } => {
            let h = super::head_of(&key, w.server.num_heads());
            if w.server.is_cleaning(h) {
                w.server.cleaning_write(&mut w.nvm, &key, &value, deleted);
            } else {
                // Cleaning finished while the request was in flight:
                // serve as a normal server-side append (same effect).
                let obj = if deleted {
                    object::encode_delete(&key)
                } else {
                    object::encode_object(&key, &value)
                };
                let (_, _, addr) = w.server.write_request(&mut w.nvm, &key, obj.len());
                w.nvm.write(addr, &obj);
            }
            OpOutcome::Finished { start, cleaning: true }
        }

        St::WriteReply { key, obj, start, crash_chunks } => {
            // Server applied the metadata update at service time; the
            // reply carries (head, offset) — mutate + post the data now.
            let (_head, _off, addr) = w.server.write_request(&mut w.nvm, &key, obj.len());
            match crash_chunks {
                Some(chunks) => {
                    let ErdaWorld { nvm, fabric, .. } = w;
                    fabric.post_write_partial(now, nvm, addr, &obj, chunks);
                    // Client dies: op never completes, nothing recorded.
                    OpOutcome::Crashed
                }
                None => {
                    let ack = w.fabric.write_done(now, obj.len());
                    {
                        let ErdaWorld { nvm, fabric, .. } = w;
                        fabric.post_write(now, nvm, addr, &obj);
                    }
                    OpOutcome::Continue(St::WriteAck { start, cleaning: false }, ack)
                }
            }
        }

        St::WriteAck { start, cleaning } => OpOutcome::Finished { start, cleaning },
    }
}

/// One simulated client thread (closed loop: one op in flight).
pub struct ErdaClient {
    src: OpSource,
    ops_left: u64,
    cfg: ClientConfig,
    st: St,
}

impl ErdaClient {
    pub fn new(src: OpSource, ops: u64, cfg: ClientConfig) -> Self {
        ErdaClient { src, ops_left: ops, cfg, st: St::NextOp }
    }

    /// Client leaves the run (finished or crashed).
    fn die(&mut self, w: &mut ErdaWorld) -> Step {
        w.counters.active_clients = w.counters.active_clients.saturating_sub(1);
        self.st = St::Dead;
        Step::Done
    }
}

impl Actor<ErdaWorld> for ErdaClient {
    fn step(&mut self, w: &mut ErdaWorld, now: Time) -> Step {
        match std::mem::replace(&mut self.st, St::Dead) {
            St::NextOp => {
                let op = match self.src.next() {
                    Some(op) => op,
                    None => return self.die(w),
                };
                match begin_op(&self.cfg, w, op, now, now) {
                    OpOutcome::Continue(st, at) => {
                        self.st = st;
                        Step::At(at)
                    }
                    _ => unreachable!("every op spans at least one verb"),
                }
            }
            St::Dead => Step::Done,
            st => match advance_op(&self.cfg, w, st, now) {
                OpOutcome::Continue(st, at) => {
                    self.st = st;
                    Step::At(at)
                }
                OpOutcome::Finished { start, cleaning } => {
                    // Op finished: record + loop.
                    w.counters.record_op(start, now, cleaning);
                    self.ops_left = self.ops_left.saturating_sub(1);
                    if self.ops_left == 0 {
                        return self.die(w);
                    }
                    self.st = St::NextOp;
                    Step::At(now)
                }
                OpOutcome::Crashed => self.die(w),
            },
        }
    }
}
