//! Erda server state and server-side op handlers.
//!
//! In normal mode the server CPU only touches *metadata*: a write_with_imm
//! request makes it update the hash entry (8-byte atomic flip) and return
//! the reserved log address; object bytes then flow client → NVM through
//! the NIC without CPU involvement (§3.3). Reads never touch the CPU at
//! all. During log cleaning of a head, ops on that head fall back to
//! two-sided sends served here (§4.4).

use std::collections::HashMap;

use crate::hashtable::{entry, AtomicRegion, HashTable};
use crate::log::cleaner::{CleaningState, Phase};
use crate::log::{object, HeadId, LogConfig, LogOffset, LogStore, NO_OFFSET};
use crate::metrics::Counters;
use crate::nvm::{Nvm, NvmConfig};
use crate::rdma::Fabric;
use crate::sim::{CpuPool, Timing};
use crate::store::StoreError;

/// The Erda server: metadata hash table + log-structured store + per-head
/// cleaning state.
pub struct ErdaServer {
    pub table: HashTable,
    pub log: LogStore,
    /// Per-head cleaning state (None = normal mode).
    pub cleaning: Vec<Option<CleaningState>>,
    /// Occupancy threshold (bytes under a head) that triggers cleaning.
    pub cleaning_threshold: u32,
}

impl ErdaServer {
    pub fn new(nvm: &mut Nvm, log_cfg: LogConfig, table_cap: usize) -> Self {
        let table = HashTable::new(nvm, table_cap);
        let log = LogStore::new(log_cfg, nvm);
        let cleaning = (0..log_cfg.num_heads).map(|_| None).collect();
        ErdaServer { table, log, cleaning, cleaning_threshold: u32::MAX }
    }

    pub fn num_heads(&self) -> usize {
        self.log.num_heads()
    }

    /// Is head `h` currently being cleaned (clients must switch to sends)?
    pub fn is_cleaning(&self, h: HeadId) -> bool {
        self.cleaning[h as usize].is_some()
    }

    /// Write-request handling (§3.3): locate/create the entry, reserve log
    /// space, atomically publish the new offset, and return `(head, offset,
    /// nvm address)` — the paper's "last written address of the log" — for
    /// the client's one-sided data write.
    ///
    /// Note the paper's ordering: metadata first, data later — the §4.3
    /// window where an entry points at a not-yet-written object is real and
    /// handled by checksum fallback on the read side.
    ///
    /// During log cleaning of the head the entry discipline changes (§4.4):
    /// Notify/Merge replace the new-offset slot in place (no flip; the
    /// object still lands in Region 1 and is replicated later); Replicate
    /// reserves in Region 2 and updates the old-offset slot.
    pub fn write_request(
        &mut self,
        nvm: &mut Nvm,
        key: &[u8],
        obj_len: usize,
    ) -> (HeadId, LogOffset, crate::nvm::Addr) {
        self.try_write_request(nvm, key, obj_len).expect("write request")
    }

    /// [`ErdaServer::write_request`] with typed failure instead of panics —
    /// the [`crate::store`] facade's entry point.
    pub fn try_write_request(
        &mut self,
        nvm: &mut Nvm,
        key: &[u8],
        obj_len: usize,
    ) -> Result<(HeadId, LogOffset, crate::nvm::Addr), StoreError> {
        let max = self.log.cfg.segment_size as usize;
        if obj_len > max {
            return Err(StoreError::ValueTooLarge { size: obj_len, max });
        }
        let h = super::head_of(key, self.num_heads());
        let phase = self.cleaning[h as usize].as_ref().map(|c| c.phase);
        match phase {
            None => {
                let off = self.log.reserve(nvm, h, obj_len);
                match self.table.lookup(nvm, key) {
                    Some(slot) => {
                        let r = self.table.read_entry(nvm, slot).expect("live entry").atomic;
                        self.table.update_region(nvm, slot, r.updated(off));
                    }
                    None => {
                        self.table
                            .insert(nvm, key, h, AtomicRegion::initial(off))
                            .ok_or(StoreError::TableFull)?;
                    }
                }
                Ok((h, off, self.log.addr_of(h, off)))
            }
            Some(Phase::Notify) | Some(Phase::Merge) => {
                let off = self.log.reserve(nvm, h, obj_len);
                match self.table.lookup(nvm, key) {
                    Some(slot) => {
                        let r = self.table.read_entry(nvm, slot).expect("live entry").atomic;
                        self.table.update_region(nvm, slot, r.replaced_newest(off));
                    }
                    None => {
                        self.table
                            .insert(nvm, key, h, AtomicRegion::initial(off))
                            .ok_or(StoreError::TableFull)?;
                    }
                }
                Ok((h, off, self.log.addr_of(h, off)))
            }
            Some(Phase::Replicate) => {
                let c = self.cleaning[h as usize].as_mut().expect("cleaning");
                let off = c.region2.reserve(nvm, obj_len);
                let addr = c.region2.addr_of(off);
                c.carried.insert(key.to_vec());
                match self.table.lookup(nvm, key) {
                    Some(slot) => {
                        let r = self.table.read_entry(nvm, slot).expect("live entry").atomic;
                        self.table.update_region(nvm, slot, r.updated_no_flip(off));
                    }
                    None => {
                        let r = AtomicRegion { new_tag: true, off_a: NO_OFFSET, off_b: off };
                        self.table.insert(nvm, key, h, r).ok_or(StoreError::TableFull)?;
                    }
                }
                Ok((h, off, addr))
            }
        }
    }

    /// Client-driven repair after a detected torn object (§4.2): roll the
    /// entry back to the old offset — but only if the entry still points at
    /// the reported offset AND the object is still torn when the repair
    /// request is served. The second check distinguishes a crashed writer
    /// from the §4.3 read-write race: a racing writer's bytes land moments
    /// later and must NOT be rolled back.
    pub fn repair(&mut self, nvm: &mut Nvm, key: &[u8], torn_off: LogOffset) -> bool {
        if let Some(slot) = self.table.lookup(nvm, key) {
            let e = self.table.read_entry(nvm, slot).expect("live entry");
            let r = e.atomic;
            if r.newest() == torn_off && r.oldest() != NO_OFFSET && !self.is_cleaning(e.head_id) {
                let still_torn = !self.log.head(e.head_id).contains(torn_off)
                    || object::decode(
                        nvm.read(self.log.addr_of(e.head_id, torn_off), self.log.window(torn_off)),
                    )
                    .is_err();
                if still_torn {
                    self.table.update_region(nvm, slot, r.rolled_back());
                    return true;
                }
            }
        }
        false
    }

    /// Resolve which (chain, offset) currently holds `key`'s latest version,
    /// honoring the cleaning-phase read rules (§4.4). Returns the object
    /// bytes, or None if the key is absent / deleted / unreadable.
    pub fn local_read(&self, nvm: &Nvm, key: &[u8]) -> Option<Vec<u8>> {
        let slot = self.table.lookup(nvm, key)?;
        let e = self.table.read_entry(nvm, slot)?;
        let h = e.head_id;
        let bytes = match &self.cleaning[h as usize] {
            Some(c) if c.phase == Phase::Replicate => {
                // §4.4: old-offset beyond the reserved area = written during
                // replication = latest; otherwise serve from Region 1.
                let old = e.atomic.oldest();
                if c.is_fresh_region2(old) {
                    nvm.read_vec(c.region2.addr_of(old), c.region2.window(old))
                } else if e.atomic.newest() != NO_OFFSET {
                    let off = e.atomic.newest();
                    nvm.read_vec(self.log.addr_of(h, off), self.log.window(off))
                } else if old != NO_OFFSET {
                    // Fresh key created during replication before reserve_end
                    // cannot exist (reserve_end fixed first); treat as region2.
                    nvm.read_vec(c.region2.addr_of(old), c.region2.window(old))
                } else {
                    return None;
                }
            }
            _ => {
                let off = e.atomic.newest();
                if off == NO_OFFSET {
                    return None;
                }
                nvm.read_vec(self.log.addr_of(h, off), self.log.window(off))
            }
        };
        match object::decode(&bytes) {
            Ok(v) if !v.deleted => Some(bytes[..v.wire_len()].to_vec()),
            _ => None,
        }
    }

    /// Cleaning-mode write (two-sided, §4.4): append per phase rules and
    /// update the entry without flipping the tag.
    pub fn cleaning_write(&mut self, nvm: &mut Nvm, key: &[u8], value: &[u8], deleted: bool) {
        let h = super::head_of(key, self.num_heads());
        let obj = if deleted { object::encode_delete(key) } else { object::encode_object(key, value) };
        let phase = self.cleaning[h as usize].as_ref().map(|c| c.phase);
        match phase {
            Some(Phase::Notify) | Some(Phase::Merge) => {
                // Append to Region 1; replace the new-offset slot in place.
                let off = self.log.append_local(nvm, h, &obj);
                match self.table.lookup(nvm, key) {
                    Some(slot) => {
                        let r = self.table.read_entry(nvm, slot).expect("live").atomic;
                        self.table.update_region(nvm, slot, r.replaced_newest(off));
                    }
                    None => {
                        self.table
                            .insert(nvm, key, h, AtomicRegion::initial(off))
                            .expect("hash table full");
                    }
                }
            }
            Some(Phase::Replicate) => {
                // Append directly to Region 2 (past the reserved area);
                // update the old-offset slot; mark carried.
                let c = self.cleaning[h as usize].as_mut().expect("cleaning");
                let off = c.region2.append_local(nvm, &obj);
                c.carried.insert(key.to_vec());
                match self.table.lookup(nvm, key) {
                    Some(slot) => {
                        let r = self.table.read_entry(nvm, slot).expect("live").atomic;
                        self.table.update_region(nvm, slot, r.updated_no_flip(off));
                    }
                    None => {
                        // Fresh key during replication: newest slot empty,
                        // old slot carries the Region-2 offset.
                        let r = AtomicRegion { new_tag: true, off_a: NO_OFFSET, off_b: off };
                        self.table.insert(nvm, key, h, r).expect("hash table full");
                    }
                }
            }
            None => unreachable!("cleaning_write outside cleaning mode"),
        }
    }

    /// Entry slot address for a key's home neighborhood — what the client
    /// RDMA-reads (one contiguous hopscotch window).
    pub fn neighborhood_addr(&self, key: &[u8]) -> (crate::nvm::Addr, usize) {
        let b = self.table.bucket(key);
        // Neighborhoods never wrap (the table carries HOP_RANGE spillover
        // slots), so one contiguous window covers every candidate.
        (self.table.slot_addr(b), crate::hashtable::HOP_RANGE * entry::ENTRY_SIZE)
    }

    /// Decode the entries of a neighborhood window (client-side parsing of
    /// RDMA-read bytes).
    pub fn parse_neighborhood(bytes: &[u8], key: &[u8]) -> Option<entry::EntryView> {
        bytes
            .chunks(entry::ENTRY_SIZE)
            .filter_map(entry::decode)
            .find(|v| v.key == key)
    }
}

/// The shared world of an Erda simulation run.
pub struct ErdaWorld {
    pub nvm: Nvm,
    pub fabric: Fabric,
    pub cpu: CpuPool,
    pub server: ErdaServer,
    pub counters: Counters,
}

impl ErdaWorld {
    pub fn new(timing: Timing, nvm_cfg: NvmConfig, log_cfg: LogConfig, table_cap: usize) -> Self {
        let mut nvm = Nvm::new(nvm_cfg);
        let server = ErdaServer::new(&mut nvm, log_cfg, table_cap);
        ErdaWorld {
            nvm,
            cpu: CpuPool::new(timing.server_cores),
            fabric: Fabric::new(timing),
            server,
            counters: Counters::default(),
        }
    }

    /// Bulk-load `n` records server-side (setup phase; zero virtual time,
    /// stats reset afterwards by the driver).
    pub fn preload(&mut self, n: u64, value_size: usize) {
        self.preload_shard(n, value_size, 0, 1);
    }

    /// Bulk-load the subset of records `0..n` that [`crate::store::shard_of`]
    /// routes to `shard` of `shards` — each shard world of a scale-out
    /// cluster holds only its own partition of the key space.
    pub fn preload_shard(&mut self, n: u64, value_size: usize, shard: usize, shards: usize) {
        for i in 0..n {
            let key = crate::ycsb::key_of(i);
            if crate::store::shard_of(&key, shards) != shard {
                continue;
            }
            let value = vec![0xA5u8; value_size];
            let obj = object::encode_object(&key, &value);
            let (_, _, addr) = self.server.write_request(&mut self.nvm, &key, obj.len());
            self.nvm.write(addr, &obj);
        }
    }

    /// Drain the NIC cache completely (end-of-run settling before direct
    /// state inspection; virtual time has stopped advancing).
    pub fn settle(&mut self) {
        let ErdaWorld { nvm, fabric, .. } = self;
        fabric.flush(crate::sim::Time::MAX, nvm);
    }

    /// Convenience for tests: direct (virtual-time-free) read of a key.
    pub fn get(&self, key: &[u8]) -> Option<Vec<u8>> {
        self.server
            .local_read(&self.nvm, key)
            .and_then(|b| object::decode(&b).ok())
            .map(|v| v.value)
    }
}

/// Convenience: a map of key → value for correctness oracles in tests.
pub type Oracle = HashMap<Vec<u8>, Vec<u8>>;

#[cfg(test)]
mod tests {
    use super::*;

    fn world() -> ErdaWorld {
        ErdaWorld::new(
            Timing::default(),
            NvmConfig { capacity: 8 << 20 },
            LogConfig { region_size: 1 << 18, segment_size: 1 << 13, num_heads: 2 },
            1 << 10,
        )
    }

    #[test]
    fn preload_then_get() {
        let mut w = world();
        w.preload(50, 64);
        for i in 0..50 {
            let v = w.get(&crate::ycsb::key_of(i)).expect("present");
            assert_eq!(v, vec![0xA5u8; 64]);
        }
        assert!(w.get(b"user-missing").is_none());
    }

    #[test]
    fn write_request_publishes_metadata_before_data() {
        let mut w = world();
        let key = crate::ycsb::key_of(0);
        let obj = object::encode_object(&key, b"vvvv");
        let (_, off, addr) = w.server.write_request(&mut w.nvm, &key, obj.len());
        // Entry already points at the reserved (unwritten) offset: §4.3.
        let slot = w.server.table.lookup(&w.nvm, &key).unwrap();
        let e = w.server.table.read_entry(&w.nvm, slot).unwrap();
        assert_eq!(e.atomic.newest(), off);
        // Reading now yields nothing valid (checksum gate).
        assert!(w.get(&key).is_none());
        // After the data lands, the read succeeds.
        w.nvm.write(addr, &obj);
        assert_eq!(w.get(&key).unwrap(), b"vvvv");
    }

    #[test]
    fn update_keeps_old_version_reachable() {
        let mut w = world();
        w.preload(1, 16);
        let key = crate::ycsb::key_of(0);
        let obj2 = object::encode_object(&key, b"new-value");
        let (h, off2, addr2) = w.server.write_request(&mut w.nvm, &key, obj2.len());
        w.nvm.write(addr2, &obj2);
        let slot = w.server.table.lookup(&w.nvm, &key).unwrap();
        let at = w.server.table.read_entry(&w.nvm, slot).unwrap().atomic;
        assert_eq!(at.newest(), off2);
        let old_bytes = w.nvm.read_vec(
            w.server.log.addr_of(h, at.oldest()),
            w.server.log.window(at.oldest()),
        );
        let old = object::decode(&old_bytes).expect("old version intact");
        assert_eq!(old.value, vec![0xA5u8; 16]);
    }

    #[test]
    fn repair_rolls_back_torn_write() {
        let mut w = world();
        w.preload(1, 16);
        let key = crate::ycsb::key_of(0);
        // Update metadata but never write the object (client died).
        let (_, torn_off, _) = w.server.write_request(&mut w.nvm, &key, 64);
        assert!(w.get(&key).is_none(), "torn object must not decode");
        assert!(w.server.repair(&mut w.nvm, &key, torn_off));
        assert_eq!(w.get(&key).unwrap(), vec![0xA5u8; 16], "old version restored");
        // Repair is idempotent / guarded: a second attempt is a no-op.
        assert!(!w.server.repair(&mut w.nvm, &key, torn_off));
    }

    #[test]
    fn repair_skips_if_writer_moved_on() {
        let mut w = world();
        w.preload(1, 16);
        let key = crate::ycsb::key_of(0);
        let (_, torn_off, _) = w.server.write_request(&mut w.nvm, &key, 64);
        // Another writer completes a newer update.
        let obj3 = object::encode_object(&key, b"fresh");
        let (_, _, addr3) = w.server.write_request(&mut w.nvm, &key, obj3.len());
        w.nvm.write(addr3, &obj3);
        assert!(!w.server.repair(&mut w.nvm, &key, torn_off), "stale repair ignored");
        assert_eq!(w.get(&key).unwrap(), b"fresh");
    }

    #[test]
    fn neighborhood_parse_finds_key() {
        let mut w = world();
        w.preload(20, 16);
        let key = crate::ycsb::key_of(7);
        let (addr, len) = w.server.neighborhood_addr(&key);
        let bytes = w.nvm.read_vec(addr, len);
        let e = ErdaServer::parse_neighborhood(&bytes, &key).expect("found");
        assert_eq!(e.key, key);
    }

    #[test]
    fn delete_via_write_request_hides_key() {
        let mut w = world();
        w.preload(1, 16);
        let key = crate::ycsb::key_of(0);
        let obj = object::encode_delete(&key);
        let (_, off, addr) = w.server.write_request(&mut w.nvm, &key, obj.len());
        w.nvm.write(addr, &obj);
        assert!(w.get(&key).is_none(), "deleted object reads as absent");
    }
}
