//! The cleaner actor: drives [`crate::log::cleaner::CleaningState`] through
//! notify → merge → replicate → pointer swing + tag flip (§4.4).
//!
//! The cleaner runs on the server and *competes for the same CPU pool* as
//! two-sided request service — exactly why Fig 26 shows elevated latencies
//! during cleaning. Client ops interleave with cleaner steps in virtual
//! time; writes during merge land in Region 1 (replicated later), writes
//! during replication land in Region 2 past the reserved area.

use super::server::ErdaWorld;
use crate::hashtable::AtomicRegion;
use crate::log::cleaner::{CleaningState, Phase};
use crate::log::{object, Chain, HeadId};
use crate::sim::{Actor, Step, Time};

/// Cleaner tunables.
#[derive(Clone, Copy, Debug)]
pub struct CleanerConfig {
    /// Objects processed per scheduling step (amortizes event overhead).
    pub batch: usize,
    /// Idle polling interval when below the occupancy threshold.
    pub poll: Time,
    /// Stop after the first completed cleaning (tests / Fig 26 runs).
    pub one_shot: bool,
}

impl Default for CleanerConfig {
    fn default() -> Self {
        CleanerConfig { batch: 8, poll: 200_000, one_shot: false }
    }
}

/// One cleaner per head.
pub struct CleanerActor {
    pub head: HeadId,
    cfg: CleanerConfig,
    done_once: bool,
}

impl CleanerActor {
    pub fn new(head: HeadId, cfg: CleanerConfig) -> Self {
        CleanerActor { head, cfg, done_once: false }
    }

    /// Per-object cleaning service time (copy + checksum + NVM append).
    fn obj_service(w: &ErdaWorld, len: usize) -> Time {
        let t = &w.fabric.timing;
        t.cpu_apply + t.cpu_bytes(len) + t.nvm_write(len)
    }

    fn start_cleaning(&self, w: &mut ErdaWorld, now: Time) -> Step {
        let h = self.head as usize;
        let cfg = w.server.log.cfg;
        let region2 = Chain::new(cfg.region_size, cfg.segment_size, &mut w.nvm);
        let state = CleaningState::start(&w.server.log.head(self.head).index, region2);
        w.server.cleaning[h] = Some(state);
        // §4.4: inform connected clients, wait one maximum RTT before the
        // merge starts so in-flight one-sided ops drain.
        Step::At(now + 2 * w.fabric.timing.one_sided_rtt)
    }

    fn merge_step(&self, w: &mut ErdaWorld, now: Time) -> Step {
        let h = self.head;
        let mut busy_until = now;
        for _ in 0..self.cfg.batch {
            let item = {
                let c = w.server.cleaning[h as usize].as_mut().expect("cleaning");
                c.next_merge_item()
            };
            let (off, len) = match item {
                Some(x) => x,
                None => {
                    // Merge done → pre-reserve replication space (boundary
                    // snapshot of what clients appended during the merge).
                    let index = w.server.log.head(h).index.clone();
                    let c = w.server.cleaning[h as usize].as_mut().expect("cleaning");
                    c.begin_replication(&mut w.nvm, &index);
                    return Step::At(busy_until.max(now));
                }
            };
            let bytes = w.nvm.read_vec(w.server.log.addr_of(h, off), len as usize);
            let v = match object::decode(&bytes) {
                Ok(v) => v,
                Err(_) => continue, // torn leftover: dropped by compaction
            };
            let c = w.server.cleaning[h as usize].as_mut().expect("cleaning");
            if c.already_seen(&v.key) {
                continue; // stale version: the reverse scan saw a newer one
            }
            if v.deleted {
                // Deleted objects are removed during cleaning; free the entry.
                if let Some(slot) = w.server.table.lookup(&w.nvm, &v.key) {
                    w.server.table.remove(&mut w.nvm, slot);
                }
                continue;
            }
            // Carry the newest version into Region 2 and point the
            // old-offset slot at it (no tag flip — Figs 10–11).
            let resv = w.cpu.reserve(now, Self::obj_service(w, len as usize));
            busy_until = busy_until.max(resv.end);
            let c = w.server.cleaning[h as usize].as_mut().expect("cleaning");
            let r2off = c.region2.append_local(&mut w.nvm, &bytes);
            c.carried.insert(v.key.clone());
            if let Some(slot) = w.server.table.lookup(&w.nvm, &v.key) {
                let r = w.server.table.read_entry(&w.nvm, slot).expect("live").atomic;
                w.server.table.update_region(&mut w.nvm, slot, r.updated_no_flip(r2off));
            }
        }
        Step::At(busy_until.max(now + 1))
    }

    fn replicate_step(&self, w: &mut ErdaWorld, now: Time) -> Step {
        let h = self.head;
        let mut busy_until = now;
        for _ in 0..self.cfg.batch {
            let item = {
                let c = w.server.cleaning[h as usize].as_mut().expect("cleaning");
                c.next_repl_item()
            };
            let (r1off, len, r2slot) = match item {
                Some(x) => x,
                None => return self.complete(w, now),
            };
            let bytes = w.nvm.read_vec(w.server.log.addr_of(h, r1off), len as usize);
            let v = match object::decode(&bytes) {
                Ok(v) => v,
                Err(_) => continue, // torn client write from the merge window
            };
            if v.deleted {
                if let Some(slot) = w.server.table.lookup(&w.nvm, &v.key) {
                    w.server.table.remove(&mut w.nvm, slot);
                }
                let c = w.server.cleaning[h as usize].as_mut().expect("cleaning");
                c.carried.remove(&v.key);
                continue;
            }
            // §4.4: if the key already appeared past the reserved area (a
            // client wrote it during replication), keep that newer version.
            let skip = {
                let c = w.server.cleaning[h as usize].as_ref().expect("cleaning");
                match w.server.table.lookup(&w.nvm, &v.key) {
                    Some(slot) => {
                        let e = w.server.table.read_entry(&w.nvm, slot).expect("live");
                        c.is_fresh_region2(e.atomic.oldest())
                    }
                    None => true, // entry vanished (deleted): nothing to do
                }
            };
            if skip {
                continue;
            }
            let resv = w.cpu.reserve(now, Self::obj_service(w, len as usize));
            busy_until = busy_until.max(resv.end);
            let c = w.server.cleaning[h as usize].as_mut().expect("cleaning");
            let addr = c.region2.addr_of(r2slot);
            w.nvm.write(addr, &bytes);
            c.carried.insert(v.key.clone());
            if let Some(slot) = w.server.table.lookup(&w.nvm, &v.key) {
                let r = w.server.table.read_entry(&w.nvm, slot).expect("live").atomic;
                w.server.table.update_region(&mut w.nvm, slot, r.updated_no_flip(r2slot));
            }
        }
        Step::At(busy_until.max(now + 1))
    }

    /// Pointer swing + tag flips (Figs 12–13): Region 2 becomes Region 1.
    fn complete(&self, w: &mut ErdaWorld, now: Time) -> Step {
        let h = self.head;
        let state = w.server.cleaning[h as usize].take().expect("cleaning");
        // Flip the tag of every carried entry so the Region-2 offset in the
        // old slot becomes the newest; drop entries that carried nothing
        // (fresh keys whose only write tore during cleaning — rollback to
        // nonexistence).
        let slots: Vec<usize> = w.server.table.live_slots().collect();
        let mut flips = 0u32;
        for slot in slots {
            let e = match w.server.table.read_entry(&w.nvm, slot) {
                Some(e) => e,
                None => continue,
            };
            if e.head_id != h {
                continue;
            }
            if state.carried.contains(&e.key) {
                let r = AtomicRegion { new_tag: !e.atomic.new_tag, ..e.atomic };
                w.server.table.update_region(&mut w.nvm, slot, r);
                flips += 1;
            } else {
                w.server.table.remove(&mut w.nvm, slot);
            }
        }
        let t = &w.fabric.timing;
        let svc = flips as Time * t.cpu_hash_op / 4;
        w.cpu.reserve(now, svc);
        w.server.log.swing_head(h, state.region2);
        w.counters.cleanings_completed += 1;
        Step::At(now + 1)
    }
}

impl Actor<ErdaWorld> for CleanerActor {
    fn step(&mut self, w: &mut ErdaWorld, now: Time) -> Step {
        if self.done_once && self.cfg.one_shot {
            return Step::Done;
        }
        let phase = w.server.cleaning[self.head as usize].as_ref().map(|c| c.phase);
        match phase {
            None => {
                if w.counters.active_clients == 0 {
                    return Step::Done; // run over; let the engine quiesce
                }
                if w.server.log.occupied(self.head) >= w.server.cleaning_threshold {
                    self.start_cleaning(w, now)
                } else {
                    Step::At(now + self.cfg.poll)
                }
            }
            Some(Phase::Notify) => {
                let c = w.server.cleaning[self.head as usize].as_mut().expect("cleaning");
                c.phase = Phase::Merge;
                Step::At(now)
            }
            Some(Phase::Merge) => self.merge_step(w, now),
            Some(Phase::Replicate) => {
                let step = self.replicate_step(w, now);
                if w.server.cleaning[self.head as usize].is_none() {
                    self.done_once = true;
                    if self.cfg.one_shot {
                        return Step::Done;
                    }
                }
                step
            }
        }
    }
}
