//! The Erda protocol (§3–§4): zero-copy log-structured remote memory with
//! Remote Data Atomicity for one-sided RDMA writes to NVM.
//!
//! * [`server`] — server state (hash table + log store + cleaning) and the
//!   server-side op handlers: normal-mode metadata update, cleaning-mode
//!   two-sided reads/writes, entry repair.
//! * [`client`] — the client actor: one-sided read path (entry read →
//!   object read → checksum verify → fallback/repair), write path
//!   (write_with_imm metadata request → one-sided data write), delete,
//!   cleaning-mode send path, failure injection.
//! * [`cleaner`] — the cleaner actor driving [`crate::log::cleaner`].
//! * [`recovery`] — server crash recovery: rebuild volatile state, verify
//!   newest versions (optionally batched through the PJRT artifact), roll
//!   back torn entries.

pub mod cleaner;
pub mod client;
pub mod recovery;
pub mod server;

pub use cleaner::{CleanerActor, CleanerConfig};
pub use client::{ClientConfig, ErdaClient};
pub use recovery::{recover, BatchCheck, LocalCheck, RecoveryReport};
pub use server::{ErdaServer, ErdaWorld};

// The op-stream types moved into the scheme-agnostic facade; re-exported
// here because the Erda client consumes them directly.
pub use crate::metrics::Counters;
pub use crate::store::{OpSource, Request};

use crate::log::HeadId;

/// Deterministic, client-computable head placement: the paper sends clients
/// the head array on connect; making placement a pure function of the key
/// lets clients decide locally (and know which head is under cleaning).
pub fn head_of(key: &[u8], num_heads: usize) -> HeadId {
    ((crate::crc::fnv1a(key) >> 16) as usize % num_heads) as HeadId
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_placement_is_stable_and_in_range() {
        for n in [1usize, 2, 4, 16] {
            for i in 0..100u32 {
                let key = format!("user{i}");
                let h = head_of(key.as_bytes(), n);
                assert!((h as usize) < n);
                assert_eq!(h, head_of(key.as_bytes(), n), "stable");
            }
        }
    }

    #[test]
    fn head_placement_spreads_keys() {
        let mut counts = [0u32; 4];
        for i in 0..1000u32 {
            counts[head_of(format!("user{i:016}").as_bytes(), 4) as usize] += 1;
        }
        for (h, &c) in counts.iter().enumerate() {
            assert!(c > 100, "head {h} underloaded: {c}");
        }
    }
}
