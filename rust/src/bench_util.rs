//! Minimal statistics harness for `cargo bench` targets (`harness = false`;
//! criterion is not in the offline vendor set — DESIGN.md §3).
//!
//! Usage in a bench binary:
//! ```no_run
//! let mut b = erda::bench_util::Bench::new("substrates");
//! b.bench("crc32/4096B", || erda::crc::crc32(&vec![0u8; 4096]));
//! b.finish();
//! ```

use std::hint::black_box;
use std::time::{Duration, Instant};

/// One benchmark group printing criterion-style lines.
pub struct Bench {
    group: String,
    /// Target wall-clock per measurement (default 300 ms).
    pub budget: Duration,
    results: Vec<(String, f64)>,
    filter: Option<String>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // `cargo bench -- <filter>` support.
        let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
        Bench { group: group.into(), budget: Duration::from_millis(300), results: Vec::new(), filter }
    }

    /// Measure `f`, printing mean time/iter and iters/sec.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) {
        if let Some(filt) = &self.filter {
            if !name.contains(filt.as_str()) && !self.group.contains(filt.as_str()) {
                return;
            }
        }
        // Warmup + calibration: find an iteration count that fills ~budget.
        let t0 = Instant::now();
        black_box(f());
        let one = t0.elapsed().max(Duration::from_nanos(20));
        let iters = (self.budget.as_nanos() / one.as_nanos()).clamp(1, 1_000_000) as u64;
        // Measure.
        let t0 = Instant::now();
        for _ in 0..iters {
            black_box(f());
        }
        let total = t0.elapsed();
        let per = total.as_nanos() as f64 / iters as f64;
        let (scaled, unit) = if per < 1_000.0 {
            (per, "ns")
        } else if per < 1_000_000.0 {
            (per / 1_000.0, "µs")
        } else {
            (per / 1_000_000.0, "ms")
        };
        println!(
            "{:<44} time: {:>10.3} {}/iter   ({:.0} iter/s, {} iters)",
            format!("{}/{}", self.group, name),
            scaled,
            unit,
            1e9 / per,
            iters
        );
        self.results.push((name.into(), per));
    }

    /// Result lookup (for throughput-style derived prints).
    pub fn result_ns(&self, name: &str) -> Option<f64> {
        self.results.iter().find(|(n, _)| n == name).map(|&(_, v)| v)
    }

    pub fn finish(self) {
        println!("{}: {} benchmarks", self.group, self.results.len());
    }
}
