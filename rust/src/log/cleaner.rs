//! Lock-free log cleaning state (§4.4, Figs 9–13) — the pure bookkeeping.
//!
//! Cleaning of one head proceeds in two phases:
//!
//! 1. **Merge** — reverse-scan Region 1 from the last written address at
//!    cleaning start. The first occurrence of a key is its newest version in
//!    the merge window and is copied to Region 2; later (= older) versions
//!    are skipped; deleted objects are dropped (and their entries freed).
//! 2. **Replication** — objects appended by clients *during* the merge
//!    (between the snapshot boundary and the merge end) are copied into a
//!    space reserved in Region 2; writes arriving during replication go to
//!    Region 2 directly, past the reserved area.
//!
//! Throughout, the entry's **new tag is never flipped**: the new-offset slot
//! keeps serving Region-1 addresses while the old-offset slot accumulates
//! Region-2 addresses (Figs 10–11). Completion swings the head pointer to
//! Region 2 and flips the tags of every carried entry in one pass (Figs
//! 12–13). The driving actor lives in `erda::cleaner`; this module only
//! holds the state and the pure transition helpers so they can be tested in
//! isolation.

use std::collections::HashSet;

use super::store::{Chain, LogOffset};

/// Which phase the cleaner is in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Clients have been notified; merge starts after the notification
    /// window (one maximum RTT, §4.4).
    Notify,
    Merge,
    Replicate,
}

/// Cleaning state for one head.
#[derive(Debug)]
pub struct CleaningState {
    pub phase: Phase,
    /// Region 2: the chain being compacted into.
    pub region2: Chain,
    /// Snapshot of Region 1's append index at cleaning start; merge scans
    /// it in reverse.
    pub merge_snapshot: Vec<(LogOffset, u32)>,
    /// How many snapshot entries remain to merge (we pop from the back).
    pub merge_remaining: usize,
    /// Keys whose newest merge-window version was already carried.
    pub seen: HashSet<Vec<u8>>,
    /// Number of Region-1 index entries that existed at cleaning start —
    /// everything past this was appended during merge and needs replication.
    pub boundary: usize,
    /// Replication work list: (region1 offset, len, pre-reserved region2
    /// offset) for each object appended during the merge phase.
    pub repl_set: Vec<(LogOffset, u32, LogOffset)>,
    pub repl_remaining: usize,
    /// End of the reserved replication area in Region 2: old-offset values
    /// greater than this were written by clients during replication and are
    /// the latest version (§4.4's read disambiguation rule).
    pub reserved_end: LogOffset,
    /// Keys whose old-offset slot currently holds a Region-2 address —
    /// exactly the entries whose tag must flip at completion.
    pub carried: HashSet<Vec<u8>>,
}

impl CleaningState {
    /// Start cleaning: snapshot Region 1's index, allocate Region 2.
    pub fn start(region1_index: &[(LogOffset, u32)], region2: Chain) -> Self {
        CleaningState {
            phase: Phase::Notify,
            region2,
            merge_snapshot: region1_index.to_vec(),
            merge_remaining: region1_index.len(),
            seen: HashSet::new(),
            boundary: region1_index.len(),
            repl_set: Vec::new(),
            repl_remaining: 0,
            reserved_end: 0,
            carried: HashSet::new(),
        }
    }

    /// Next merge item (newest-first), or None when the scan is done.
    pub fn next_merge_item(&mut self) -> Option<(LogOffset, u32)> {
        if self.merge_remaining == 0 {
            return None;
        }
        self.merge_remaining -= 1;
        Some(self.merge_snapshot[self.merge_remaining])
    }

    /// Merge-phase dedup: returns true if `key`'s newest version was already
    /// carried (the current item is stale and must be skipped).
    pub fn already_seen(&mut self, key: &[u8]) -> bool {
        !self.seen.insert(key.to_vec())
    }

    /// Transition Merge → Replicate: `region1_index` is Region 1's live
    /// index *now*; entries past the boundary were appended during merge.
    /// Pre-reserves their Region-2 slots and fixes `reserved_end`.
    pub fn begin_replication(
        &mut self,
        nvm: &mut crate::nvm::Nvm,
        region1_index: &[(LogOffset, u32)],
    ) {
        assert_eq!(self.phase, Phase::Merge);
        self.repl_set = region1_index[self.boundary.min(region1_index.len())..]
            .iter()
            .map(|&(off, len)| {
                let r2 = self.region2.reserve(nvm, len as usize);
                (off, len, r2)
            })
            .collect();
        self.repl_remaining = self.repl_set.len();
        self.reserved_end = self.region2.tail;
        self.phase = Phase::Replicate;
    }

    /// Next replication item (oldest-first keeps version order), or None.
    pub fn next_repl_item(&mut self) -> Option<(LogOffset, u32, LogOffset)> {
        if self.repl_remaining == 0 {
            return None;
        }
        let item = self.repl_set[self.repl_set.len() - self.repl_remaining];
        self.repl_remaining -= 1;
        Some(item)
    }

    /// §4.4 read rule during replication: is the old-offset value `off` a
    /// client write that superseded the replication copy?
    pub fn is_fresh_region2(&self, off: LogOffset) -> bool {
        off != super::store::NO_OFFSET && off >= self.reserved_end
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nvm::{Nvm, NvmConfig};

    fn chain(nvm: &mut Nvm) -> Chain {
        Chain::new(4096, 1024, nvm)
    }

    #[test]
    fn merge_iterates_newest_first() {
        let mut nvm = Nvm::new(NvmConfig { capacity: 1 << 20 });
        let idx = vec![(0u32, 10u32), (16, 10), (32, 10)];
        let mut c = CleaningState::start(&idx, chain(&mut nvm));
        c.phase = Phase::Merge;
        assert_eq!(c.next_merge_item(), Some((32, 10)));
        assert_eq!(c.next_merge_item(), Some((16, 10)));
        assert_eq!(c.next_merge_item(), Some((0, 10)));
        assert_eq!(c.next_merge_item(), None);
    }

    #[test]
    fn dedup_skips_stale_versions() {
        let mut nvm = Nvm::new(NvmConfig { capacity: 1 << 20 });
        let mut c = CleaningState::start(&[], chain(&mut nvm));
        assert!(!c.already_seen(b"k1"), "first occurrence is fresh");
        assert!(c.already_seen(b"k1"), "second occurrence is stale");
        assert!(!c.already_seen(b"k2"));
    }

    #[test]
    fn replication_reserves_space_and_sets_boundary() {
        let mut nvm = Nvm::new(NvmConfig { capacity: 1 << 20 });
        let idx = vec![(0u32, 64u32)];
        let mut c = CleaningState::start(&idx, chain(&mut nvm));
        c.phase = Phase::Merge;
        while c.next_merge_item().is_some() {}
        // Two objects appended during merge.
        let live = vec![(0u32, 64u32), (64, 100), (168, 50)];
        c.begin_replication(&mut nvm, &live);
        assert_eq!(c.phase, Phase::Replicate);
        assert_eq!(c.repl_set.len(), 2);
        assert_eq!(c.reserved_end, c.region2.tail);
        // Oldest-first order.
        let first = c.next_repl_item().unwrap();
        assert_eq!((first.0, first.1), (64, 100));
        // A client write after reservation lands beyond reserved_end.
        let w = c.region2.reserve(&mut nvm, 40);
        assert!(c.is_fresh_region2(w));
        assert!(!c.is_fresh_region2(first.2));
    }

    #[test]
    fn no_merge_window_means_empty_replication_of_prior_items() {
        let mut nvm = Nvm::new(NvmConfig { capacity: 1 << 20 });
        let mut c = CleaningState::start(&[], chain(&mut nvm));
        c.phase = Phase::Merge;
        c.begin_replication(&mut nvm, &[]);
        assert_eq!(c.next_repl_item(), None);
        assert_eq!(c.reserved_end, 0);
    }
}
