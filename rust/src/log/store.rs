//! Head array + region chains + segment-aware append allocation.

use crate::nvm::{Addr, Nvm};

/// Index into the head array (the paper's 1-byte Head ID).
pub type HeadId = u8;

/// 31-bit logical offset within a region chain — the unit stored in the
/// hash entry's 8-byte atomic region.
pub type LogOffset = u32;

/// Sentinel for "no offset" (all-ones in 31 bits). Offset 0 is valid.
pub const NO_OFFSET: LogOffset = 0x7FFF_FFFF;

/// Geometry of the log. The paper uses 1 GB regions / 8 MB segments; the
/// simulated default is 1 MB / 64 KB so figure runs and tests stay fast —
/// every structural rule (no segment spanning, region chaining, 31-bit
/// offsets) is unchanged.
#[derive(Clone, Copy, Debug)]
pub struct LogConfig {
    pub region_size: u32,
    pub segment_size: u32,
    pub num_heads: usize,
}

impl Default for LogConfig {
    fn default() -> Self {
        LogConfig { region_size: 1 << 20, segment_size: 1 << 16, num_heads: 4 }
    }
}

/// One append-only chain of equally-sized contiguous regions (Fig 5).
/// A head owns one chain; the cleaner's "Region 2" and the baselines'
/// staging/destination areas are chains too.
#[derive(Clone, Debug)]
pub struct Chain {
    pub region_size: u32,
    pub segment_size: u32,
    /// NVM base address of each region, in chain order.
    pub regions: Vec<Addr>,
    /// Next logical append offset (the "last written address of the log",
    /// maintained by the server; volatile — rebuilt by recovery).
    pub tail: LogOffset,
    /// Volatile append index: (offset, wire length) of every reservation,
    /// in order. DRAM-side bookkeeping used by the cleaner's reverse scan;
    /// rebuilt from NVM by the recovery forward scan.
    pub index: Vec<(LogOffset, u32)>,
}

impl Chain {
    /// A chain with one initial region allocated.
    pub fn new(region_size: u32, segment_size: u32, nvm: &mut Nvm) -> Self {
        assert!(region_size % segment_size == 0, "regions hold whole segments");
        Chain {
            region_size,
            segment_size,
            regions: vec![nvm.alloc(region_size as usize)],
            tail: 0,
            index: Vec::new(),
        }
    }

    /// Is `off` a resolvable offset within the currently-chained regions?
    /// (Recovery uses this to reject dangling pointers left by a crash
    /// mid-cleaning: an old-offset slot may reference a Region 2 that was
    /// discarded.)
    pub fn contains(&self, off: LogOffset) -> bool {
        off != NO_OFFSET && (off / self.region_size) < self.regions.len() as u32
    }

    /// NVM address of logical offset `off`.
    pub fn addr_of(&self, off: LogOffset) -> Addr {
        debug_assert_ne!(off, NO_OFFSET);
        let r = (off / self.region_size) as usize;
        let within = off % self.region_size;
        self.regions[r] + within as Addr
    }

    /// Bytes readable contiguously from `off` without crossing its segment
    /// boundary (objects never span segments, so this bounds any object).
    pub fn window(&self, off: LogOffset) -> usize {
        (self.segment_size - off % self.segment_size) as usize
    }

    /// Reserve `len` bytes, observing the segment no-span rule and chaining
    /// a new region when the current one is full. The reservation is 8-byte
    /// aligned (lets recovery skip-scan torn areas). Returns the logical
    /// offset; the caller fills the bytes (server locally, or a remote
    /// client via one-sided write).
    pub fn reserve(&mut self, nvm: &mut Nvm, len: usize) -> LogOffset {
        let seg = self.segment_size;
        assert!(len as u32 <= seg, "object larger than a segment: {len}");
        assert!(len > 0, "zero-length reservation");
        let mut off = (self.tail + 7) & !7;
        // An object exceeding the current segment starts the next one (§3.3).
        if off % seg + len as u32 > seg {
            off = (off / seg + 1) * seg;
        }
        // Region chaining for scalability (§3.2.2, Fig 5).
        let needed_end = off as u64 + len as u64;
        assert!(needed_end <= NO_OFFSET as u64, "31-bit log offset space exhausted");
        while needed_end > self.regions.len() as u64 * self.region_size as u64 {
            self.regions.push(nvm.alloc(self.region_size as usize));
        }
        self.tail = off + len as u32;
        self.index.push((off, len as u32));
        off
    }

    /// Server-local append: reserve + write through the memory bus.
    pub fn append_local(&mut self, nvm: &mut Nvm, bytes: &[u8]) -> LogOffset {
        let off = self.reserve(nvm, bytes.len());
        nvm.write(self.addr_of(off), bytes);
        off
    }

    /// Rebuild `tail` and the volatile index by forward skip-scanning NVM
    /// (crash recovery: DRAM bookkeeping was lost). Returns the index.
    pub fn rebuild_index(&mut self, nvm: &Nvm) -> Vec<(LogOffset, u32)> {
        use super::object;
        let seg = self.segment_size;
        let total = self.regions.len() as u32 * self.region_size;
        let mut index = Vec::new();
        let mut tail = 0u32;
        let mut off = 0u32;
        while off + object::OBJ_HDR as u32 <= total {
            let window = (seg - off % seg).min(total - off) as usize;
            match object::decode(nvm.read(self.addr_of(off), window)) {
                Ok(v) => {
                    let len = v.wire_len() as u32;
                    index.push((off, len));
                    off += len;
                    tail = off;
                    off = (off + 7) & !7;
                }
                Err(_) => {
                    // Torn or unwritten: skip-scan at the reservation
                    // alignment until the next decodable object.
                    off += 8;
                }
            }
        }
        self.tail = tail;
        self.index = index.clone();
        index
    }
}

/// The log-structured store over all heads.
pub struct LogStore {
    pub cfg: LogConfig,
    heads: Vec<Chain>,
}

impl LogStore {
    /// Allocate one initial region per head.
    pub fn new(cfg: LogConfig, nvm: &mut Nvm) -> Self {
        assert!(cfg.num_heads > 0 && cfg.num_heads <= 256, "head ID is 1 byte");
        let heads = (0..cfg.num_heads)
            .map(|_| Chain::new(cfg.region_size, cfg.segment_size, nvm))
            .collect();
        LogStore { cfg, heads }
    }

    pub fn num_heads(&self) -> usize {
        self.heads.len()
    }

    pub fn head(&self, h: HeadId) -> &Chain {
        &self.heads[h as usize]
    }

    pub fn head_mut(&mut self, h: HeadId) -> &mut Chain {
        &mut self.heads[h as usize]
    }

    /// NVM address of logical offset `off` under head `h`.
    pub fn addr_of(&self, h: HeadId, off: LogOffset) -> Addr {
        self.heads[h as usize].addr_of(off)
    }

    /// Segment-bounded contiguous window at `off` (same for all heads).
    pub fn window(&self, off: LogOffset) -> usize {
        (self.cfg.segment_size - off % self.cfg.segment_size) as usize
    }

    /// Current tail (last written address) of head `h`.
    pub fn tail(&self, h: HeadId) -> LogOffset {
        self.heads[h as usize].tail
    }

    /// Reserve under head `h` (see [`Chain::reserve`]).
    pub fn reserve(&mut self, nvm: &mut Nvm, h: HeadId, len: usize) -> LogOffset {
        self.heads[h as usize].reserve(nvm, len)
    }

    /// Server-local append under head `h`.
    pub fn append_local(&mut self, nvm: &mut Nvm, h: HeadId, bytes: &[u8]) -> LogOffset {
        self.heads[h as usize].append_local(nvm, bytes)
    }

    /// Occupied bytes under head `h` (tail position = log length incl. holes).
    pub fn occupied(&self, h: HeadId) -> u32 {
        self.heads[h as usize].tail
    }

    /// Replace head `h`'s chain — the final pointer swing of log cleaning
    /// (Fig 12: Region 2 becomes Region 1).
    pub fn swing_head(&mut self, h: HeadId, chain: Chain) {
        self.heads[h as usize] = chain;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::log::object;
    use crate::nvm::NvmConfig;

    fn small() -> (LogStore, Nvm) {
        let mut nvm = Nvm::new(NvmConfig { capacity: 1 << 22 });
        let cfg = LogConfig { region_size: 4096, segment_size: 1024, num_heads: 2 };
        let store = LogStore::new(cfg, &mut nvm);
        (store, nvm)
    }

    #[test]
    fn append_and_read_back() {
        let (mut s, mut nvm) = small();
        let obj = object::encode_object(b"k1", b"value-1");
        let off = s.append_local(&mut nvm, 0, &obj);
        let got = nvm.read(s.addr_of(0, off), obj.len());
        assert_eq!(got, &obj[..]);
        assert_eq!(object::decode(got).unwrap().key, b"k1");
    }

    #[test]
    fn reservations_are_8_aligned_and_monotone() {
        let (mut s, mut nvm) = small();
        let mut last = 0;
        for i in 0..20 {
            let off = s.reserve(&mut nvm, 0, 10 + i);
            assert_eq!(off % 8, 0);
            assert!(off >= last);
            last = off;
        }
    }

    #[test]
    fn objects_do_not_span_segments() {
        let (mut s, mut nvm) = small();
        // Fill most of segment 0, then reserve something that won't fit.
        s.reserve(&mut nvm, 0, 1000);
        let off = s.reserve(&mut nvm, 0, 100);
        assert_eq!(off, 1024, "second object must start at next segment");
        assert!(off / 1024 == (off + 99) / 1024);
    }

    #[test]
    fn region_chaining_extends_capacity() {
        let (mut s, mut nvm) = small();
        assert_eq!(s.head(0).regions.len(), 1);
        for _ in 0..5 {
            s.reserve(&mut nvm, 0, 1000);
        }
        assert!(s.head(0).regions.len() >= 2, "second region must be chained");
        // Offsets past the first region still resolve to valid NVM addrs.
        let off = s.reserve(&mut nvm, 0, 64);
        let addr = s.addr_of(0, off);
        nvm.write(addr, &[9u8; 64]);
        assert_eq!(nvm.read(addr, 64), &[9u8; 64][..]);
    }

    #[test]
    fn heads_are_independent() {
        let (mut s, mut nvm) = small();
        let a = s.append_local(&mut nvm, 0, &object::encode_object(b"a", b"1"));
        let b = s.append_local(&mut nvm, 1, &object::encode_object(b"b", b"2"));
        assert_eq!(a, b, "same logical offset under different heads");
        assert_ne!(s.addr_of(0, a), s.addr_of(1, b));
    }

    #[test]
    fn window_bounds_by_segment() {
        let (s, _) = small();
        assert_eq!(s.window(0), 1024);
        assert_eq!(s.window(1000), 24);
        assert_eq!(s.window(1024), 1024);
    }

    #[test]
    fn rebuild_index_after_volatile_loss() {
        let (mut s, mut nvm) = small();
        let objs: Vec<_> = (0..8)
            .map(|i| object::encode_object(format!("key{i}").as_bytes(), &vec![i as u8; 50]))
            .collect();
        let offs: Vec<_> = objs.iter().map(|o| s.append_local(&mut nvm, 0, o)).collect();
        let tail_before = s.tail(0);
        // Simulate crash: wipe volatile bookkeeping.
        let h = s.head_mut(0);
        h.tail = 0;
        h.index.clear();
        let index = s.head_mut(0).rebuild_index(&nvm);
        assert_eq!(index.len(), 8);
        assert_eq!(index.iter().map(|&(o, _)| o).collect::<Vec<_>>(), offs);
        assert_eq!(s.tail(0), tail_before);
    }

    #[test]
    fn rebuild_index_skips_torn_object() {
        let (mut s, mut nvm) = small();
        let a = object::encode_object(b"ok-1", b"aaaa");
        let torn = object::encode_object(b"torn", &vec![3u8; 64]);
        let c = object::encode_object(b"ok-2", b"cccc");
        s.append_local(&mut nvm, 0, &a);
        let toff = s.reserve(&mut nvm, 0, torn.len());
        // Persist only the first 16 bytes of the torn object.
        nvm.write(s.addr_of(0, toff), &torn[..16]);
        s.append_local(&mut nvm, 0, &c);
        let h = s.head_mut(0);
        h.tail = 0;
        h.index.clear();
        let index = s.head_mut(0).rebuild_index(&nvm);
        let keys: Vec<_> = index
            .iter()
            .map(|&(o, l)| object::decode(nvm.read(s.addr_of(0, o), l as usize)).unwrap().key)
            .collect();
        assert_eq!(keys, vec![b"ok-1".to_vec(), b"ok-2".to_vec()]);
    }

    #[test]
    fn swing_head_replaces_chain() {
        let (mut s, mut nvm) = small();
        s.append_local(&mut nvm, 0, &object::encode_object(b"old", b"1"));
        let mut fresh = Chain::new(4096, 1024, &mut nvm);
        let off = fresh.append_local(&mut nvm, &object::encode_object(b"new", b"2"));
        s.swing_head(0, fresh);
        let v = object::decode(nvm.read(s.addr_of(0, off), 64)).unwrap();
        assert_eq!(v.key, b"new");
        assert_eq!(s.head(0).index.len(), 1);
    }

    #[test]
    #[should_panic(expected = "larger than a segment")]
    fn oversized_reservation_panics() {
        let (mut s, mut nvm) = small();
        s.reserve(&mut nvm, 0, 2048);
    }
}
