//! Object codec: `[tag u8 | crc u32 | klen u8 | vlen u16 | key | value]`.
//!
//! * `tag` — bit 0 is the paper's 1-bit delete tag; remaining bits reserved.
//! * `crc` — CRC32 over the **entire encoded object with the crc field
//!   zeroed** (same convention as the L1 Pallas kernel pipeline in
//!   python/compile/model.py, so the AOT batch verifier and this codec
//!   interoperate byte-for-byte).
//! * deleted objects carry the key but no value (Fig 3) — saves space.

use crate::crc::crc32;

/// Fixed header size: tag(1) + crc(4) + klen(1) + vlen(2).
pub const OBJ_HDR: usize = 8;
/// Maximum key length the codec (and the hash-table entry) supports.
pub const MAX_KEY: usize = 24;
/// Maximum value length (paper sweeps 16 B – 4096 B).
pub const MAX_VALUE: usize = u16::MAX as usize;

const TAG_DELETED: u8 = 0x01;

/// A decoded object.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ObjectView {
    pub deleted: bool,
    pub crc: u32,
    pub key: Vec<u8>,
    pub value: Vec<u8>,
}

impl ObjectView {
    /// Encoded byte length of this object.
    pub fn wire_len(&self) -> usize {
        OBJ_HDR + self.key.len() + self.value.len()
    }
}

/// Why a decode failed — the distinction drives the consistency protocol:
/// `BadChecksum`/`Garbage` mean a torn or unwritten object (fall back to the
/// old version), not a protocol error.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeError {
    /// Buffer shorter than the header or the declared lengths.
    Truncated,
    /// Declared key length exceeds MAX_KEY (unwritten/garbage bytes).
    Garbage,
    /// CRC mismatch: object is torn or partially persisted.
    BadChecksum,
}

fn checksum(buf: &mut [u8]) -> u32 {
    buf[1..5].fill(0);
    crc32(buf)
}

fn encode(deleted: bool, key: &[u8], value: &[u8]) -> Vec<u8> {
    assert!(!key.is_empty(), "key must be non-empty");
    assert!(key.len() <= MAX_KEY, "key too long: {}", key.len());
    assert!(value.len() <= MAX_VALUE, "value too long: {}", value.len());
    let mut buf = Vec::with_capacity(OBJ_HDR + key.len() + value.len());
    buf.push(if deleted { TAG_DELETED } else { 0 });
    buf.extend_from_slice(&[0u8; 4]); // crc placeholder
    buf.push(key.len() as u8);
    buf.extend_from_slice(&(value.len() as u16).to_le_bytes());
    buf.extend_from_slice(key);
    buf.extend_from_slice(value);
    let crc = crc32(&buf);
    buf[1..5].copy_from_slice(&crc.to_le_bytes());
    buf
}

/// Encode a normal object (Fig 2).
pub fn encode_object(key: &[u8], value: &[u8]) -> Vec<u8> {
    encode(false, key, value)
}

/// Encode a deleted object (Fig 3): key only, no value.
pub fn encode_delete(key: &[u8]) -> Vec<u8> {
    encode(true, key, &[])
}

/// Total encoded size for a (klen, vlen) pair.
pub fn wire_size(klen: usize, vlen: usize) -> usize {
    OBJ_HDR + klen + vlen
}

/// Decode and verify an object from the front of `buf`.
///
/// `buf` may be longer than the object (log reads fetch a whole max-size
/// window); the declared lengths bound what is checksummed.
pub fn decode(buf: &[u8]) -> Result<ObjectView, DecodeError> {
    if buf.len() < OBJ_HDR {
        return Err(DecodeError::Truncated);
    }
    let tag = buf[0];
    let stored_crc = u32::from_le_bytes(buf[1..5].try_into().expect("4 bytes"));
    let klen = buf[5] as usize;
    let vlen = u16::from_le_bytes(buf[6..8].try_into().expect("2 bytes")) as usize;
    if klen > MAX_KEY || klen == 0 {
        return Err(DecodeError::Garbage);
    }
    let total = OBJ_HDR + klen + vlen;
    if buf.len() < total {
        return Err(DecodeError::Truncated);
    }
    let mut scratch = buf[..total].to_vec();
    if checksum(&mut scratch) != stored_crc {
        return Err(DecodeError::BadChecksum);
    }
    Ok(ObjectView {
        deleted: tag & TAG_DELETED != 0,
        crc: stored_crc,
        key: buf[OBJ_HDR..OBJ_HDR + klen].to_vec(),
        value: buf[OBJ_HDR + klen..total].to_vec(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Rng;

    #[test]
    fn roundtrip_normal() {
        let buf = encode_object(b"user42", b"the value");
        let v = decode(&buf).expect("valid");
        assert!(!v.deleted);
        assert_eq!(v.key, b"user42");
        assert_eq!(v.value, b"the value");
        assert_eq!(v.wire_len(), buf.len());
    }

    #[test]
    fn roundtrip_deleted_has_no_value() {
        let buf = encode_delete(b"user42");
        assert_eq!(buf.len(), OBJ_HDR + 6);
        let v = decode(&buf).expect("valid");
        assert!(v.deleted);
        assert_eq!(v.key, b"user42");
        assert!(v.value.is_empty());
    }

    #[test]
    fn decode_with_trailing_garbage() {
        let mut buf = encode_object(b"k", b"v");
        buf.extend_from_slice(&[0xFF; 100]);
        let v = decode(&buf).expect("valid despite trailing bytes");
        assert_eq!(v.value, b"v");
    }

    #[test]
    fn torn_object_fails_checksum() {
        let buf = encode_object(b"key", &vec![7u8; 300]);
        for cut in [OBJ_HDR + 3 + 1, OBJ_HDR + 3 + 150, buf.len() - 1] {
            let mut torn = buf.clone();
            torn[cut..].iter_mut().for_each(|b| *b = 0);
            assert_eq!(decode(&torn), Err(DecodeError::BadChecksum), "cut at {cut}");
        }
    }

    #[test]
    fn unwritten_memory_is_garbage_or_truncated() {
        assert!(matches!(decode(&[0u8; 4]), Err(DecodeError::Truncated)));
        // All-zero header: klen = 0 -> Garbage.
        assert_eq!(decode(&[0u8; 64]), Err(DecodeError::Garbage));
        // Random bytes: overwhelmingly BadChecksum or Garbage.
        let mut rng = Rng::new(8);
        let mut buf = vec![0u8; 128];
        for _ in 0..50 {
            rng.fill_bytes(&mut buf);
            assert!(decode(&buf).is_err());
        }
    }

    #[test]
    fn single_bit_flip_detected_everywhere() {
        let buf = encode_object(b"bitflip", b"payload-payload");
        for i in 0..buf.len() {
            let mut b = buf.clone();
            b[i] ^= 0x40;
            assert!(decode(&b).is_err(), "flip at byte {i} undetected");
        }
    }

    #[test]
    #[should_panic(expected = "key too long")]
    fn oversized_key_panics() {
        encode_object(&[0u8; 25], b"");
    }
}
