//! Log-structured object store (§3.2.2, Figs 4–5 of the paper).
//!
//! Data live in append-only logs: a fixed **head array** links chains of
//! contiguous memory regions (the paper registers 1 GB regions divided into
//! 8 MB segments; the simulated geometry is configurable and defaults
//! smaller so tests stay fast — the structure is identical). An object never
//! spans two segments; when one would, the writer skips to the next segment
//! boundary. When a region fills, another is allocated, registered, and
//! linked under the same head (Fig 5).
//!
//! Objects are `[delete-tag | crc32 | key-value]` (Figs 2–3). Our codec
//! carries explicit `klen`/`vlen` fields (3 bytes) that the paper's 5-byte
//! header leaves implicit; EXPERIMENTS.md's Table 1 notes the constant.

pub mod cleaner;
pub mod object;
pub mod store;

pub use object::{decode, encode_delete, encode_object, DecodeError, ObjectView, OBJ_HDR};
pub use store::{Chain, HeadId, LogConfig, LogOffset, LogStore, NO_OFFSET};
