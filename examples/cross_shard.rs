//! The co-simulated cluster: every shard world in ONE event heap, cluster-
//! level clients whose windows span shards, and a truly global client-NIC
//! bound.
//!
//! Three acts, all through the unified `store` facade:
//!
//! 1. **One window, many shards** — a single client with a deep window
//!    issues ops that route to different shard worlds at issue time; both
//!    shards complete ops from the same window and the makespan shrinks
//!    accordingly.
//! 2. **Scale-out with the window held** — per-shard CPUs multiply with
//!    the shard count, so windowed write throughput grows while the window
//!    stays busy (Little's-law utilization).
//! 3. **The global NIC bound** — the SAME run metered through a 1-channel
//!    shared ingress: every shard's issue path serializes on one client
//!    NIC, capping the aggregate no matter how many shards are added.
//!
//! Run: `cargo run --release --example cross_shard`

use erda::store::{Cluster, ClusterBuilder, Scheme};
use erda::ycsb::Workload;

const CLIENTS: usize = 8;
const WINDOW: usize = 8;

fn base(shards: usize) -> ClusterBuilder {
    Cluster::builder()
        .scheme(Scheme::Erda)
        .shards(shards)
        .clients(CLIENTS)
        .window(WINDOW)
        .ops_per_client(300)
        .workload(Workload::UpdateOnly)
        .records(256)
        .value_size(1024)
        .warmup(0)
}

fn main() {
    // 1. One client, two shards: the window spans both.
    let outcome = Cluster::builder()
        .scheme(Scheme::Erda)
        .shards(2)
        .clients(1)
        .window(8)
        .ops_per_client(400)
        .workload(Workload::ReadOnly)
        .records(128)
        .value_size(256)
        .warmup(0)
        .run().unwrap();
    println!("one client, window 8, 2 shards (YCSB-C):");
    for (sh, p) in outcome.per_shard.iter().enumerate() {
        println!("  shard {sh}: {:>5} ops completed from the one window", p.ops);
    }
    assert!(
        outcome.per_shard.iter().all(|p| p.ops > 0),
        "the window must span both shards"
    );

    // 2 + 3. Scale-out: free vs metered through a 1-channel shared ingress.
    println!("\nscale-out, write-only, 1 KiB (free vs 1-channel shared-NIC ingress):");
    println!(
        "  {:>6} {:>12} {:>10} {:>12} {:>14}",
        "shards", "free KOp/s", "win util", "nic KOp/s", "nic wait µs"
    );
    for shards in [1usize, 2, 4] {
        let free = base(shards).run().unwrap().stats;
        let nic = base(shards).ingress(1).run().unwrap().stats;
        // Little's law: mean in-flight = throughput × mean latency; the
        // fraction of `clients × window` it fills is window utilization.
        let in_flight = free.kops() * 1e3 * free.latency.mean_ns() * 1e-9;
        println!(
            "  {shards:>6} {:>12.2} {:>10.2} {:>12.2} {:>14.1}",
            free.kops(),
            in_flight / (CLIENTS * WINDOW) as f64,
            nic.kops(),
            nic.mean_ingress_wait_ns() / 1000.0
        );
        assert_eq!(nic.ingress_admitted, nic.ops, "every shard meters through ONE queue");
    }
    println!("\nco-simulated cluster OK ✓");
}
