//! End-to-end driver: the full system on a real workload, all layers
//! composing (deliverable (b)'s end-to-end validation run).
//!
//! Runs all three schemes over the four YCSB mixes on the simulated
//! testbed, reports the paper's headline metrics (throughput, latency,
//! server-CPU cost, NVM write bytes/op), then closes the loop through the
//! AOT stack: a crash + batch-verified recovery using the PJRT-compiled
//! Pallas CRC32 kernel. The run is recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example ycsb_bench`

use erda::sim::MS;
use erda::workload::{run, DriverConfig, SchemeSel};
use erda::ycsb::{Workload, WorkloadConfig};

fn main() {
    let clients = 8;
    let ops = 1000;
    println!(
        "YCSB end-to-end: {clients} clients × {ops} ops, 1000 records, value = 256 B, Zipfian 0.99\n"
    );
    println!(
        "{:<14} {:<18} {:>10} {:>12} {:>14} {:>14}",
        "workload", "scheme", "KOp/s", "mean µs", "CPU µs/op", "NVM B/op"
    );
    for wl in Workload::ALL {
        for scheme in SchemeSel::ALL {
            let cfg = DriverConfig {
                scheme,
                workload: WorkloadConfig {
                    workload: wl,
                    record_count: 1000,
                    value_size: 256,
                    theta: 0.99,
                    seed: 0xE2DA,
                },
                clients,
                ops_per_client: ops,
                warmup: 5 * MS,
                nvm_capacity: 128 << 20,
                ..DriverConfig::default()
            };
            let s = run(&cfg);
            assert_eq!(s.read_misses, 0, "{scheme:?}/{wl:?} lost reads");
            println!(
                "{:<14} {:<18} {:>10.2} {:>12.2} {:>14.2} {:>14.1}",
                wl.id(),
                scheme.label(),
                s.kops(),
                s.latency.mean_us(),
                s.cpu_per_op_ns() / 1e3,
                s.nvm_programmed_bytes as f64 / s.ops.max(1) as f64,
            );
        }
        println!();
    }

    // Close the loop through the AOT stack: crash + PJRT-verified recovery.
    match erda::runtime::Runtime::load_default() {
        Ok(rt) => {
            use erda::erda::{recover, ErdaWorld};
            use erda::log::{object, LogConfig};
            use erda::nvm::NvmConfig;
            use erda::runtime::PjrtCheck;
            use erda::sim::Timing;

            let mut w = ErdaWorld::new(
                Timing::default(),
                NvmConfig { capacity: 32 << 20 },
                LogConfig::default(),
                1 << 12,
            );
            w.preload(1000, 256);
            let key = erda::ycsb::key_of(123);
            let obj = object::encode_object(&key, &vec![9u8; 256]);
            let (_, _, addr) = w.server.write_request(&mut w.nvm, &key, obj.len());
            w.nvm.write(addr, &obj[..40]); // torn
            for h in 0..w.server.num_heads() {
                let head = w.server.log.head_mut(h as u8);
                head.tail = 0;
                head.index.clear();
            }
            let report = recover(&mut w.server, &mut w.nvm, &mut PjrtCheck(&rt));
            println!(
                "recovery through the AOT Pallas kernel: {} entries checked, {} rolled back ✓",
                report.entries_checked, report.entries_rolled_back
            );
            assert_eq!(report.entries_rolled_back, 1);
        }
        Err(e) => println!("(skipping PJRT recovery pass: {e}; run `make artifacts`)"),
    }
}
