//! End-to-end driver: the full system on a real workload, all layers
//! composing (deliverable (b)'s end-to-end validation run).
//!
//! Runs all three schemes over the four YCSB mixes on the simulated
//! testbed through the unified `store` facade — the scheme is just a loop
//! variable — reports the paper's headline metrics (throughput, latency,
//! server-CPU cost, NVM write bytes/op), then closes the loop through the
//! AOT stack: a crash + batch-verified recovery using the PJRT-compiled
//! Pallas CRC32 kernel. The run is recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example ycsb_bench`

use erda::sim::MS;
use erda::store::{Cluster, Scheme};
use erda::ycsb::Workload;

fn main() {
    let clients = 8;
    let ops = 1000;
    println!(
        "YCSB end-to-end: {clients} clients × {ops} ops, 1000 records, value = 256 B, Zipfian 0.99\n"
    );
    println!(
        "{:<14} {:<18} {:>10} {:>12} {:>14} {:>14}",
        "workload", "scheme", "KOp/s", "mean µs", "CPU µs/op", "NVM B/op"
    );
    for wl in Workload::ALL {
        for scheme in Scheme::ALL {
            let s = Cluster::builder()
                .scheme(scheme)
                .workload(wl)
                .records(1000)
                .value_size(256)
                .theta(0.99)
                .seed(0xE2DA)
                .clients(clients)
                .ops_per_client(ops)
                .warmup(5 * MS)
                .nvm_capacity(128 << 20)
                .run()
                .unwrap()
                .stats;
            assert_eq!(s.read_misses, 0, "{scheme:?}/{wl:?} lost reads");
            println!(
                "{:<14} {:<18} {:>10.2} {:>12.2} {:>14.2} {:>14.1}",
                wl.id(),
                scheme.label(),
                s.kops(),
                s.latency.mean_us(),
                s.cpu_per_op_ns() / 1e3,
                s.nvm_programmed_bytes as f64 / s.ops.max(1) as f64,
            );
        }
        println!();
    }

    // Close the loop through the AOT stack: crash + batch-verified recovery.
    match erda::runtime::Runtime::load_default() {
        Ok(rt) => {
            use erda::runtime::PjrtCheck;
            use erda::store::RemoteStore;
            use erda::ycsb::key_of;

            let mut db = Cluster::builder()
                .scheme(Scheme::Erda)
                .nvm_capacity(32 << 20)
                .records(1000)
                .value_size(256)
                .preload(1000, 256)
                .build_db();
            db.crash_during_put(&key_of(123), &vec![9u8; 256], 0).expect("inject");
            db.crash().expect("erda store");
            let report = db.recover_with(&mut PjrtCheck(&rt)).expect("recovery");
            println!(
                "recovery through the AOT Pallas kernel: {} entries checked, {} rolled back ✓",
                report.entries_checked, report.entries_rolled_back
            );
            assert_eq!(report.entries_rolled_back, 1);
            let restored = db.get(&key_of(123)).expect("get");
            assert_eq!(restored, Some(vec![0xA5u8; 256]), "rolled back to old version");
        }
        Err(e) => println!("(skipping PJRT recovery pass: {e}; run `make artifacts`)"),
    }
}
