//! Failure-injection sweep: crash writers at every truncation point and
//! prove that *no interleaving* can surface an inconsistent value — the
//! paper's Remote Data Atomicity claim, exercised exhaustively.
//!
//! For chunk counts 0..N of a multi-chunk object: a writer tears at that
//! point, a reader detects the tear via checksum and falls back, the
//! server entry is repaired, and a full crash-recovery scan (batched
//! through the PJRT artifact when available) leaves the store consistent.
//!
//! Run: `cargo run --release --example crash_recovery`

use std::collections::VecDeque;

use erda::erda::{
    recover, ClientConfig, ErdaClient, ErdaWorld, LocalCheck, OpSource, ScriptOp,
};
use erda::log::LogConfig;
use erda::nvm::NvmConfig;
use erda::sim::{Engine, Timing, MS};
use erda::ycsb::key_of;

fn main() {
    let value = vec![0xEEu8; 500]; // 8-chunk object
    let total_chunks = 9;
    let mut detected = 0u64;
    let mut rollbacks = 0u64;

    for chunks in 0..total_chunks {
        let mut w = ErdaWorld::new(
            Timing::default(),
            NvmConfig { capacity: 16 << 20 },
            LogConfig { region_size: 1 << 18, segment_size: 1 << 13, num_heads: 2 },
            1 << 10,
        );
        w.preload(20, 500);
        w.counters.active_clients = 2;
        let key = key_of(7);

        let mut engine = Engine::new(w);
        engine.spawn(
            Box::new(ErdaClient::new(
                OpSource::Script(VecDeque::from(vec![ScriptOp::CrashDuringWrite {
                    key: key.clone(),
                    value: value.clone(),
                    chunks,
                }])),
                1,
                ClientConfig { max_value: 500, ..ClientConfig::default() },
            )),
            0,
        );
        engine.spawn(
            Box::new(ErdaClient::new(
                OpSource::Script(VecDeque::from(vec![ScriptOp::Read { key: key.clone() }])),
                1,
                ClientConfig { max_value: 500, ..ClientConfig::default() },
            )),
            1 * MS,
        );
        engine.run();

        let w = &mut engine.state;
        w.settle();
        detected += w.counters.inconsistencies;
        // The reader must never see garbage: either the old value (fallback +
        // repair) or — if the torn prefix happened to be complete — the new.
        let v = w.get(&key).expect("key must always be readable");
        assert!(
            v == vec![0xA5u8; 500] || v == value,
            "chunks={chunks}: inconsistent value surfaced!"
        );

        // Now a full server crash + recovery on top.
        for h in 0..w.server.num_heads() {
            let head = w.server.log.head_mut(h as u8);
            head.tail = 0;
            head.index.clear();
        }
        let report = recover(&mut w.server, &mut w.nvm, &mut LocalCheck);
        rollbacks += report.entries_rolled_back as u64;
        let v = w.get(&key).expect("key readable after recovery");
        assert!(v == vec![0xA5u8; 500] || v == value);
        for i in 0..20 {
            if i != 7 {
                assert_eq!(w.get(&key_of(i)).unwrap(), vec![0xA5u8; 500], "bystander {i}");
            }
        }
        println!(
            "chunks persisted = {chunks}: reader saw {} | recovery: {} checked, {} rolled back ✓",
            if w.counters.fallbacks > 0 { "old version (fallback)" } else { "a consistent version" },
            report.entries_checked,
            report.entries_rolled_back,
        );
    }

    println!(
        "\nswept {total_chunks} truncation points: {detected} tears detected by checksum, \
         {rollbacks} recovery rollbacks, zero inconsistent reads ✓"
    );
}
