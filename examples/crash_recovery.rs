//! Failure-injection sweep: crash writers at every truncation point and
//! prove that *no interleaving* can surface an inconsistent value — the
//! paper's Remote Data Atomicity claim, exercised exhaustively through the
//! unified `store` facade.
//!
//! For chunk counts 0..N of a multi-chunk object: a writer tears at that
//! point, a reader detects the tear via checksum and falls back, the
//! server entry is repaired, and a full crash-recovery scan (batched
//! through the PJRT artifact when available) leaves the store consistent.
//!
//! Run: `cargo run --release --example crash_recovery`

use erda::log::LogConfig;
use erda::sim::MS;
use erda::store::{Cluster, RemoteStore, Request, Scheme};
use erda::ycsb::key_of;

fn main() {
    let value = vec![0xEEu8; 500]; // 8-chunk object
    let total_chunks = 9;
    let mut detected = 0u64;
    let mut rollbacks = 0u64;

    for chunks in 0..total_chunks {
        let outcome = Cluster::builder()
            .scheme(Scheme::Erda)
            .log(LogConfig { region_size: 1 << 18, segment_size: 1 << 13, num_heads: 2 })
            .nvm_capacity(16 << 20)
            .records(20)
            .value_size(500)
            .preload(20, 500)
            .clients(0)
            .warmup(0)
            .script(vec![Request::CrashDuringPut {
                key: key_of(7),
                value: value.clone(),
                chunks,
            }])
            .script_at(1 * MS, vec![Request::Get { key: key_of(7) }])
            .run().unwrap();

        detected += outcome.stats.inconsistencies_detected;
        let mut db = outcome.db;
        // The reader must never see garbage: either the old value (fallback +
        // repair) or — if the torn prefix happened to be complete — the new.
        let v = db.get(&key_of(7)).unwrap().expect("key must always be readable");
        assert!(
            v == vec![0xA5u8; 500] || v == value,
            "chunks={chunks}: inconsistent value surfaced!"
        );

        // Now a full server crash + recovery on top.
        db.crash().unwrap();
        let report = db.recover().unwrap();
        rollbacks += report.entries_rolled_back as u64;
        let v = db.get(&key_of(7)).unwrap().expect("key readable after recovery");
        assert!(v == vec![0xA5u8; 500] || v == value);
        for i in 0..20 {
            if i != 7 {
                assert_eq!(
                    db.get(&key_of(i)).unwrap(),
                    Some(vec![0xA5u8; 500]),
                    "bystander {i}"
                );
            }
        }
        println!(
            "chunks persisted = {chunks}: reader saw {} | recovery: {} checked, {} rolled back ✓",
            if outcome.stats.fallback_reads > 0 {
                "old version (fallback)"
            } else {
                "a consistent version"
            },
            report.entries_checked,
            report.entries_rolled_back,
        );
    }

    println!(
        "\nswept {total_chunks} truncation points: {detected} tears detected by checksum, \
         {rollbacks} recovery rollbacks, zero inconsistent reads ✓"
    );
}
