//! Log cleaning under live load (§4.4 / Fig 26): watch a head fill up,
//! get compacted by the two-phase lock-free cleaner while clients keep
//! reading and writing, and compare latencies in and out of cleaning.
//!
//! Run: `cargo run --release --example log_cleaning`

use erda::erda::{CleanerActor, CleanerConfig, ClientConfig, ErdaClient, ErdaWorld, OpSource};
use erda::log::LogConfig;
use erda::nvm::NvmConfig;
use erda::sim::{Engine, Timing};
use erda::ycsb::{key_of, Generator, Workload, WorkloadConfig};

fn main() {
    let mut world = ErdaWorld::new(
        Timing::default(),
        NvmConfig { capacity: 128 << 20 },
        LogConfig { region_size: 1 << 20, segment_size: 1 << 14, num_heads: 2 },
        1 << 12,
    );
    world.preload(128, 1024);
    world.server.cleaning_threshold = 256 << 10; // compact at 256 KiB/head
    world.counters.active_clients = 4;

    let occupancy_before: Vec<u32> =
        (0..2).map(|h| world.server.log.occupied(h as u8)).collect();

    let mut engine = Engine::new(world);
    for c in 0..4 {
        let gen = Generator::new(
            WorkloadConfig {
                workload: Workload::UpdateHeavy,
                record_count: 128,
                value_size: 1024,
                theta: 0.99,
                seed: 11,
            },
            c,
        );
        engine.spawn(
            Box::new(ErdaClient::new(
                OpSource::Ycsb(gen),
                1500,
                ClientConfig { max_value: 1024, ..ClientConfig::default() },
            )),
            0,
        );
    }
    for h in 0..2u8 {
        engine.spawn(Box::new(CleanerActor::new(h, CleanerConfig::default())), 0);
    }
    let end = engine.run();
    let w = &mut engine.state;
    w.settle();

    println!("virtual time:        {:.2} ms", end as f64 / 1e6);
    println!("cleanings completed: {}", w.counters.cleanings_completed);
    for h in 0..2u8 {
        println!(
            "head {h}: occupancy {:>8} B (preload was {} B)",
            w.server.log.occupied(h),
            occupancy_before[h as usize],
        );
    }
    println!(
        "\nops:                   {} ({} during cleaning)",
        w.counters.ops_measured + w.counters.latency_during_cleaning.count() as u64,
        w.counters.latency_during_cleaning.count()
    );
    println!("mean latency normal:   {:>8.2} µs", w.counters.latency.mean_us());
    if w.counters.latency_during_cleaning.count() > 0 {
        println!(
            "mean latency cleaning: {:>8.2} µs  (two-sided send path, Fig 26)",
            w.counters.latency_during_cleaning.mean_us()
        );
    }
    println!("read misses:           {}", w.counters.read_misses);

    assert!(w.counters.cleanings_completed >= 1, "cleaning must have triggered");
    assert_eq!(w.counters.read_misses, 0, "no key may be lost across cleaning");
    for i in 0..128 {
        assert!(w.get(&key_of(i)).is_some(), "key {i} lost");
    }
    println!("\nall 128 keys alive and consistent across {} cleanings ✓", w.counters.cleanings_completed);
}
