//! Log cleaning under live load (§4.4 / Fig 26): watch heads fill up, get
//! compacted by the two-phase lock-free cleaner while clients keep reading
//! and writing, and compare latencies in and out of cleaning — all through
//! the unified `store` facade.
//!
//! Run: `cargo run --release --example log_cleaning`

use erda::log::LogConfig;
use erda::store::{Cluster, RemoteStore, Scheme};
use erda::ycsb::{key_of, Workload};

fn main() {
    let outcome = Cluster::builder()
        .scheme(Scheme::Erda)
        .log(LogConfig { region_size: 1 << 20, segment_size: 1 << 14, num_heads: 2 })
        .nvm_capacity(128 << 20)
        .workload(Workload::UpdateHeavy)
        .records(128)
        .value_size(1024)
        .preload(128, 1024)
        .clients(4)
        .ops_per_client(1500)
        .seed(11)
        .warmup(0)
        .cleaning_threshold(256 << 10) // compact at 256 KiB/head
        .run().unwrap();

    let s = &outcome.stats;
    let mut db = outcome.db;

    println!("virtual time:        {:.2} ms", s.duration_ns as f64 / 1e6);
    println!("cleanings completed: {}", s.cleanings);
    for h in 0..2u8 {
        println!(
            "head {h}: occupancy {:>8} B after compaction",
            db.log_occupied(h).expect("erda store"),
        );
    }
    println!(
        "\nops:                   {} ({} during cleaning)",
        s.ops,
        s.latency_cleaning.count()
    );
    println!("mean latency normal:   {:>8.2} µs", s.latency.mean_us());
    if s.latency_cleaning.count() > 0 {
        println!(
            "mean latency cleaning: {:>8.2} µs  (two-sided send path, Fig 26)",
            s.latency_cleaning.mean_us()
        );
    }
    println!("read misses:           {}", s.read_misses);

    assert!(s.cleanings >= 1, "cleaning must have triggered");
    assert_eq!(s.read_misses, 0, "no key may be lost across cleaning");
    for i in 0..128 {
        assert!(db.get(&key_of(i)).unwrap().is_some(), "key {i} lost");
    }
    println!("\nall 128 keys alive and consistent across {} cleanings ✓", s.cleanings);
}
