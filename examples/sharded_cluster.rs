//! Scale-out: partition the key space across independent server worlds and
//! watch the cluster grow past a single server's ceiling — all through the
//! unified `store` facade.
//!
//! The paper's Erda design is single-server, but its one-sided data path
//! (no server CPU involvement) is exactly what makes horizontal scale-out
//! cheap: clients route deterministically (`store::shard_of`, FNV-1a over
//! the key) to any number of shard servers without coordinating with their
//! CPUs. The CPU-bound baselines, by contrast, need the extra servers: this
//! example runs Redo Logging at 1, 2 and 4 shards to show the CPU ceiling
//! lifting, then demonstrates per-shard crash recovery — one shard fails
//! and recovers while the others never notice.
//!
//! Run: `cargo run --release --example sharded_cluster`

use erda::store::{Cluster, RemoteStore, Scheme};
use erda::ycsb::{key_of, Workload};

fn main() {
    // 1. The CPU-bound baseline scales out with shards.
    println!("Redo Logging, 16 clients, YCSB-A, 256 B values:");
    let mut first = 0.0f64;
    for shards in [1usize, 2, 4] {
        let outcome = Cluster::builder()
            .scheme(Scheme::RedoLogging)
            .shards(shards)
            .clients(16)
            .ops_per_client(200)
            .workload(Workload::UpdateHeavy)
            .records(256)
            .value_size(256)
            .warmup(0)
            .run().unwrap();
        let kops = outcome.stats.kops();
        if shards == 1 {
            first = kops;
        }
        let per: Vec<String> =
            outcome.per_shard.iter().map(|s| format!("{:.1}", s.kops())).collect();
        println!(
            "  {shards} shard(s): {kops:>7.2} KOp/s  ({:.2}x, per-shard [{}])",
            kops / first,
            per.join(", ")
        );
        assert_eq!(outcome.stats.ops, 16 * 200, "every client must finish");
    }

    // 2. Erda over 4 shards: same typed KV surface, routing by key.
    let mut db = Cluster::builder()
        .scheme(Scheme::Erda)
        .shards(4)
        .records(64)
        .value_size(128)
        .preload(64, 128)
        .build_db();
    let spread = (0..64u64).map(|i| db.shard_of_key(&key_of(i))).fold([0u32; 4], |mut a, s| {
        a[s] += 1;
        a
    });
    println!("\nErda over 4 shards: 64 preloaded keys spread {spread:?}");
    db.put(&key_of(9), &vec![0x42u8; 128]).unwrap();
    assert_eq!(db.get(&key_of(9)).unwrap(), Some(vec![0x42u8; 128]));

    // 3. Per-shard failure: tear a write, crash ONLY that shard, recover it.
    let victim_key = key_of(11);
    let victim = db.shard_of_key(&victim_key);
    db.crash_during_put(&victim_key, &vec![0xEEu8; 128], 1).unwrap();
    db.crash_shard(victim).unwrap();
    let report = db.recover_shard(victim).unwrap();
    println!(
        "shard {victim} crashed + recovered: {} entries checked, {} rolled back",
        report.entries_checked, report.entries_rolled_back
    );
    assert_eq!(report.entries_rolled_back, 1);
    assert_eq!(
        db.get(&victim_key).unwrap(),
        Some(vec![0xA5u8; 128]),
        "torn update rolled back to the preloaded version"
    );
    // Every other key — including the fresh write on another shard — intact.
    assert_eq!(db.get(&key_of(9)).unwrap(), Some(vec![0x42u8; 128]));
    for i in 0..64u64 {
        assert!(db.get(&key_of(i)).unwrap().is_some(), "key {i} lost");
    }
    println!("\nall 64 keys alive; other shards never noticed ✓");
}
