//! Open-loop, windowed clients: the async pipeline that makes Erda's
//! headroom visible at saturation instead of one-op-at-a-time latency.
//!
//! Three acts, all through the unified `store` facade:
//!
//! 1. **Window sweep (closed loop)** — the same clients, but each keeps
//!    `window` ops in flight. Erda's reads never touch a server CPU, so its
//!    throughput keeps climbing with the window; Redo Logging hits the c/s
//!    CPU ceiling and flattens.
//! 2. **Open loop** — ops arrive from a Poisson process regardless of
//!    completions. Below saturation achieved == offered; past it the
//!    client-side queue grows and the gap is measurable (offered vs
//!    achieved, queue depth).
//! 3. **Client-NIC ingress** — metering every op issue through a shared
//!    c-server ingress queue bounds the pipeline the way a real shared NIC
//!    would.
//!
//! Run: `cargo run --release --example open_loop`

use erda::store::{Cluster, Scheme};
use erda::ycsb::{Arrival, Workload};

fn main() {
    // 1. Closed loop, growing window: Erda keeps scaling (its reads never
    // touch a server CPU), Redo Logging stays pinned at the CPU ceiling.
    println!("window sweep (8 clients, YCSB-C, 256 B):");
    println!("  {:>7} {:>12} {:>12}", "window", "erda KOp/s", "redo KOp/s");
    for window in [1usize, 2, 4, 8, 16] {
        let kops = |scheme: Scheme| {
            Cluster::builder()
                .scheme(scheme)
                .clients(8)
                .window(window)
                .ops_per_client(150)
                .workload(Workload::ReadOnly)
                .records(256)
                .value_size(256)
                .warmup(0)
                .run()
                .unwrap()
                .stats
                .kops()
        };
        println!("  {window:>7} {:>12.2} {:>12.2}", kops(Scheme::Erda), kops(Scheme::RedoLogging));
    }

    // 2. Open loop: a Poisson arrival process per client. Crank the rate
    // past what the window can carry and watch the queue grow.
    println!("\nopen loop (Erda, 4 clients, window 4, Poisson arrivals):");
    println!(
        "  {:>12} {:>14} {:>14} {:>10} {:>11}",
        "rate op/s", "offered KOp/s", "achieved KOp/s", "achieved%", "mean queue"
    );
    for rate in [20_000.0f64, 60_000.0, 200_000.0] {
        let stats = Cluster::builder()
            .scheme(Scheme::Erda)
            .clients(4)
            .window(4)
            .arrival(Arrival::Poisson { rate })
            .ops_per_client(400)
            .workload(Workload::UpdateHeavy)
            .records(256)
            .value_size(256)
            .warmup(0)
            .run()
            .unwrap()
            .stats;
        println!(
            "  {rate:>12.0} {:>14.2} {:>14.2} {:>9.0}% {:>11.1}",
            stats.offered_kops(),
            stats.kops(),
            stats.achieved_fraction() * 100.0,
            stats.mean_queue_depth()
        );
        assert_eq!(stats.ops, 4 * 400, "the backlog drains once arrivals stop");
    }

    // 3. Shared client-NIC ingress: one DMA channel serializes the whole
    // pipeline; four channels mostly free it again.
    println!("\nclient-NIC ingress (Erda, 8 clients, window 8, 1 KiB values):");
    for (label, channels) in [("unmetered", None), ("1 channel", Some(1)), ("4 channels", Some(4))]
    {
        let mut b = Cluster::builder()
            .scheme(Scheme::Erda)
            .clients(8)
            .window(8)
            .ops_per_client(150)
            .workload(Workload::UpdateHeavy)
            .records(256)
            .value_size(1024)
            .warmup(0);
        if let Some(c) = channels {
            b = b.ingress(c);
        }
        let stats = b.run().unwrap().stats;
        println!(
            "  {label:>10}: {:>8.2} KOp/s, mean ingress wait {:>7.0} ns",
            stats.kops(),
            stats.mean_ingress_wait_ns()
        );
    }
    println!("\nopen-loop pipeline OK ✓");
}
