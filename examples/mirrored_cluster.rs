//! Replication: give every shard a synchronously-written RDMA mirror and
//! survive a primary failure — all through the unified `store` facade.
//!
//! With `.mirrored(true)` every put replays on the shard's mirror world
//! over the shared fabric before it ACKs (the mirror's integrity rides on
//! Erda's existing checksum gate — no primary coordination needed), so a
//! failed primary can be replaced by its mirror with `fail_primary` +
//! `promote_mirror`: the promoted replica recovers onto its last
//! checksum-consistent version. The run also shows the honest cost of
//! availability: mirrored throughput drops (the op waits for BOTH
//! persists) and NVM writes double — with the mirror share accounted
//! separately, never folded into primary totals.
//!
//! Run: `cargo run --release --example mirrored_cluster`

use erda::store::{Cluster, RemoteStore, Scheme};
use erda::ycsb::{key_of, Workload};

fn main() {
    // 1. Unreplicated vs mirrored: same seed, same workload.
    let run = |mirrored: bool| {
        Cluster::builder()
            .scheme(Scheme::Erda)
            .shards(2)
            .mirrored(mirrored)
            .clients(4)
            .window(2)
            .ops_per_client(300)
            .workload(Workload::UpdateOnly)
            .records(128)
            .value_size(256)
            .warmup(0)
            .run()
            .unwrap()
    };
    let plain = run(false);
    let mirrored = run(true);
    println!("Erda, 4 clients, window 2, update-only, 256 B, 2 shards:");
    println!(
        "  unreplicated: {:>7.2} KOp/s, mean {:.1} µs, {} NVM bytes",
        plain.stats.kops(),
        plain.stats.latency.mean_us(),
        plain.stats.nvm_programmed_bytes
    );
    println!(
        "  mirrored:     {:>7.2} KOp/s, mean {:.1} µs, {} NVM bytes \
         ({} at mirrors, mean mirror leg {:.1} µs)",
        mirrored.stats.kops(),
        mirrored.stats.latency.mean_us(),
        mirrored.stats.nvm_programmed_bytes,
        mirrored.stats.mirror_nvm_programmed_bytes,
        mirrored.stats.mean_mirror_leg_us()
    );
    assert_eq!(mirrored.stats.ops, 4 * 300, "mirroring must not lose ops");
    assert_eq!(mirrored.stats.mirror_legs, mirrored.stats.ops, "every put replicated");

    // 2. Failover: tear a write on one primary, lose that primary, promote
    // its mirror, and read the last consistent version back.
    let mut db = mirrored.db;
    let victim_key = key_of(7);
    let victim = db.shard_of_key(&victim_key);
    let before = db.get(&victim_key).unwrap().expect("key 7 live after the run");
    db.crash_during_put(&victim_key, &vec![0xEEu8; 256], 1).unwrap();
    db.fail_primary(victim).unwrap();
    let report = db.promote_mirror(victim).unwrap();
    println!(
        "\nshard {victim} failed over: {} entries checked on the promoted mirror, \
         {} rolled back",
        report.entries_checked, report.entries_rolled_back
    );
    assert_eq!(
        db.get(&victim_key).unwrap(),
        Some(before),
        "promoted mirror serves the pre-tear version"
    );
    for i in 0..128u64 {
        assert!(db.get(&key_of(i)).unwrap().is_some(), "key {i} lost in failover");
    }
    println!("all 128 keys alive on the promoted cluster ✓");
}
