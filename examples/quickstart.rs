//! Quickstart: bring up a cluster through the unified `store` facade, run
//! scripted operations through the simulated RDMA fabric, and watch the
//! consistency machinery work — including a torn write detected by checksum
//! and repaired. The scheme is a runtime parameter: change `Scheme::Erda`
//! to `Scheme::RedoLogging` or `Scheme::ReadAfterWrite` and the same
//! program runs the paper's baselines.
//!
//! Run: `cargo run --release --example quickstart`

use erda::sim::MS;
use erda::store::{Cluster, RemoteStore, Request, Scheme};
use erda::ycsb::key_of;

fn main() {
    // 1. A server with 4 log heads and a hopscotch metadata table, all in
    //    simulated NVM behind a simulated RDMA fabric — plus three scripted
    //    clients:
    //    * a well-behaved one: update, read back, delete;
    //    * a crashing one whose one-sided write tears mid-transfer;
    //    * a late reader that trips over the torn object, falls back to the
    //      previous version, and has the server repair the entry.
    let outcome = Cluster::builder()
        .scheme(Scheme::Erda)
        .heads(4)
        .nvm_capacity(32 << 20)
        .records(100)
        .value_size(128)
        .preload(100, 128)
        .clients(0)
        .warmup(0)
        .script(vec![
            Request::Put { key: key_of(1), value: vec![0x11; 128] },
            Request::Get { key: key_of(1) },
            Request::Put { key: key_of(2), value: vec![0x22; 128] },
            Request::Get { key: key_of(2) },
            Request::Delete { key: key_of(3) },
            Request::Get { key: key_of(3) }, // miss: deleted
        ])
        .script(vec![Request::CrashDuringPut {
            key: key_of(5),
            value: vec![0xEE; 128],
            chunks: 1,
        }])
        .script_at(2 * MS, vec![Request::Get { key: key_of(5) }])
        .run().unwrap();

    // 2. The run's stats tell the §4.2 consistency story.
    let s = &outcome.stats;
    println!("server up: 100 preloaded objects, 4 heads, hopscotch table");
    println!("ops completed:    {} over {} DES events", s.ops, s.events);
    println!("mean latency:     {:.2} µs", s.latency.mean_us());
    println!("read misses:      {} (the deleted key)", s.read_misses);
    println!("inconsistencies:  {} (torn write caught by CRC)", s.inconsistencies_detected);
    println!("fallback reads:   {}", s.fallback_reads);
    println!("entry repairs:    {}", s.repairs);
    println!(
        "server CPU busy:  {:.1} µs (writes only — reads are one-sided)",
        s.server_cpu_busy_ns as f64 / 1e3
    );
    assert_eq!(s.inconsistencies_detected, 1, "torn object must be flagged");
    assert_eq!(s.fallback_reads, 1, "reader must fall back to the old version");
    assert_eq!(s.repairs, 1, "server entry must be rolled back");

    // 3. The settled store is directly inspectable afterwards.
    let mut db = outcome.db;
    assert_eq!(db.get(&key_of(1)).unwrap(), Some(vec![0x11u8; 128]));
    assert_eq!(db.get(&key_of(2)).unwrap(), Some(vec![0x22u8; 128]));
    assert!(db.get(&key_of(3)).unwrap().is_none(), "deleted");
    assert_eq!(
        db.get(&key_of(5)).unwrap(),
        Some(vec![0xA5u8; 128]),
        "torn update rolled back to the preloaded version"
    );
    println!("\nfinal state checks passed ✓");
}
