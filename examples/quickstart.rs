//! Quickstart: bring up an Erda world, run a handful of scripted operations
//! through the simulated RDMA fabric, and watch the consistency machinery
//! work — including a torn write detected by checksum and repaired.
//!
//! Run: `cargo run --release --example quickstart`

use std::collections::VecDeque;

use erda::erda::{ClientConfig, ErdaClient, ErdaWorld, OpSource, ScriptOp};
use erda::log::LogConfig;
use erda::nvm::NvmConfig;
use erda::sim::{Engine, Timing, MS};
use erda::ycsb::key_of;

fn main() {
    // 1. A server with 4 log heads and a hopscotch metadata table, all in
    //    simulated NVM behind a simulated RDMA fabric.
    let mut world = ErdaWorld::new(
        Timing::default(),
        NvmConfig { capacity: 32 << 20 },
        LogConfig { region_size: 1 << 18, segment_size: 1 << 13, num_heads: 4 },
        1 << 12,
    );
    world.preload(100, 128);
    world.counters.active_clients = 3;
    println!("server up: 100 preloaded objects, 4 heads, hopscotch table");

    let mut engine = Engine::new(world);

    // 2. A well-behaved client: update, read back, delete.
    let ops = vec![
        ScriptOp::Update { key: key_of(1), value: vec![0x11; 128] },
        ScriptOp::Read { key: key_of(1) },
        ScriptOp::Update { key: key_of(2), value: vec![0x22; 128] },
        ScriptOp::Read { key: key_of(2) },
        ScriptOp::Delete { key: key_of(3) },
        ScriptOp::Read { key: key_of(3) }, // miss: deleted
    ];
    let n_ops = ops.len() as u64;
    engine.spawn(
        Box::new(ErdaClient::new(
            OpSource::Script(VecDeque::from(ops)),
            n_ops,
            ClientConfig { max_value: 128, ..ClientConfig::default() },
        )),
        0,
    );

    // 3. A crashing client: its one-sided write tears mid-transfer.
    engine.spawn(
        Box::new(ErdaClient::new(
            OpSource::Script(VecDeque::from(vec![ScriptOp::CrashDuringWrite {
                key: key_of(5),
                value: vec![0xEE; 128],
                chunks: 1,
            }])),
            1,
            ClientConfig::default(),
        )),
        0,
    );

    // 4. A late reader that trips over the torn object, falls back to the
    //    previous version, and has the server repair the entry.
    engine.spawn(
        Box::new(ErdaClient::new(
            OpSource::Script(VecDeque::from(vec![ScriptOp::Read { key: key_of(5) }])),
            1,
            ClientConfig { max_value: 128, ..ClientConfig::default() },
        )),
        2 * MS,
    );

    let end = engine.run();
    let events = engine.events();
    let w = &mut engine.state;
    w.settle();

    println!("\nvirtual makespan: {:.1} µs over {} DES events", end as f64 / 1e3, events);
    println!("ops completed:    {}", w.counters.ops_measured);
    println!("mean latency:     {:.2} µs", w.counters.latency.mean_us());
    println!("read misses:      {} (the deleted key)", w.counters.read_misses);
    println!("inconsistencies:  {} (torn write caught by CRC)", w.counters.inconsistencies);
    println!("fallback reads:   {}", w.counters.fallbacks);
    println!("entry repairs:    {}", w.counters.repairs);
    println!("server CPU busy:  {:.1} µs (writes only — reads are one-sided)",
        w.cpu.busy_ns() as f64 / 1e3);

    assert_eq!(w.get(&key_of(1)).as_deref(), Some(&vec![0x11u8; 128][..]));
    assert_eq!(w.get(&key_of(2)).as_deref(), Some(&vec![0x22u8; 128][..]));
    assert!(w.get(&key_of(3)).is_none(), "deleted");
    assert_eq!(w.get(&key_of(5)).as_deref(), Some(&vec![0xA5u8; 128][..]), "rolled back");
    println!("\nfinal state checks passed ✓");
}
