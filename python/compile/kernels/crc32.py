"""L1 Pallas kernel: batched CRC32 (IEEE 802.3, reflected, zlib-compatible).

This is the compute hot-spot of the Erda reproduction: verifying the
integrity checksum of every object in a batch (used by the server's crash
recovery scan and the log cleaner's integrity pass; see DESIGN.md §2).

Hardware adaptation (paper targets no accelerator; see DESIGN.md
§Hardware-Adaptation): instead of a per-object sequential byte loop, the
kernel keeps a *vector* of CRC states — one lane per object — and advances
all lanes together over byte *columns* of a (B, L) tile. The inner step is a
vectorized table-gather + xor + shift, which maps onto the TPU VPU; the
256-entry table (1 KiB) and the (B, L) tile live in VMEM via BlockSpec.

The kernel MUST be lowered with interpret=True on this image: real TPU
lowering emits a Mosaic custom-call that the CPU PJRT plugin cannot run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

CRC32_POLY = 0xEDB88320  # reflected IEEE 802.3 polynomial
CRC32_INIT = 0xFFFFFFFF


@functools.lru_cache(maxsize=None)
def crc32_table_np() -> np.ndarray:
    """256-entry byte-at-a-time lookup table for the reflected polynomial."""
    table = np.zeros(256, dtype=np.uint64)
    for i in range(256):
        c = np.uint64(i)
        for _ in range(8):
            c = (c >> np.uint64(1)) ^ (
                np.uint64(CRC32_POLY) if (c & np.uint64(1)) else np.uint64(0)
            )
        table[i] = c
    return table.astype(np.uint32)


def crc32_table() -> jnp.ndarray:
    return jnp.asarray(crc32_table_np())


def _crc32_kernel(data_ref, len_ref, table_ref, out_ref):
    """Pallas kernel body.

    data_ref:  u8[B, L]  padded object bytes (one object per lane)
    len_ref:   i32[B]    valid byte count per lane (<= L)
    table_ref: u32[256]  CRC lookup table
    out_ref:   u32[B]    finalized CRC per lane
    """
    # Perf note (§Perf iteration log): a transpose-once (L, B) layout was
    # tried to make the per-step column extraction contiguous — it REGRESSED
    # the AOT batch verify ~1.8× on the CPU PJRT backend (XLA already fuses
    # the strided column slice; the materialized u32 transpose dominated).
    # Keeping the (B, L) layout: lanes = batch, one dynamic column slice per
    # byte step — also the natural VPU mapping on a real TPU.
    data = data_ref[...]  # (B, L) u8; convert per column — materializing the
    # whole tile as u32 quadruples the working set for no gain (iteration #2)
    lens = len_ref[...]
    table = table_ref[...]
    n = data.shape[0]
    crc0 = jnp.full((n,), CRC32_INIT, dtype=jnp.uint32)

    def body(i, crc):
        byte = jax.lax.dynamic_slice_in_dim(data, i, 1, axis=1)[:, 0].astype(jnp.uint32)
        idx = (crc ^ byte) & jnp.uint32(0xFF)
        nxt = jnp.take(table, idx, axis=0) ^ (crc >> jnp.uint32(8))
        # Lanes whose object is shorter than i keep their state (masked step).
        return jnp.where(i < lens, nxt, crc)

    crc = jax.lax.fori_loop(0, data.shape[1], body, crc0)
    out_ref[...] = crc ^ jnp.uint32(CRC32_INIT)


def crc32_batch(data: jax.Array, lengths: jax.Array, table: jax.Array | None = None) -> jax.Array:
    """Batched CRC32 over padded byte rows.

    Args:
      data:    u8[B, L] object bytes, rows padded with anything past `lengths`.
      lengths: i32[B] number of valid bytes per row.
      table:   optional u32[256] lookup table. The AOT path MUST pass the
               table as a runtime parameter: embedding it as an HLO constant
               does not survive the HLO-text round trip to xla_extension
               0.5.1 (the parsed gather returns the *indices*, i.e. the
               constant degenerates to iota — found the hard way; see
               DESIGN.md §Perf notes). Eager/test callers may omit it.

    Returns:
      u32[B] zlib-compatible CRC32 of each row's first `lengths[i]` bytes.
    """
    if data.ndim != 2:
        raise ValueError(f"data must be rank-2 (B, L), got shape {data.shape}")
    if lengths.shape != (data.shape[0],):
        raise ValueError(
            f"lengths shape {lengths.shape} does not match batch {data.shape[0]}"
        )
    if table is None:
        table = crc32_table()
    b = data.shape[0]
    return pl.pallas_call(
        _crc32_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.uint32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(data.astype(jnp.uint8), lengths.astype(jnp.int32), table)
