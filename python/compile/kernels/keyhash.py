"""L1 Pallas kernel: batched FNV-1a (32-bit) key hashing.

Erda's metadata hash table (hopscotch) maps object keys to buckets. The Rust
side (rust/src/hashtable) uses FNV-1a-32 for the bucket hash; this kernel is
the batch version used for bulk-load preprocessing and must agree with Rust
bit-for-bit (asserted by integration tests through the AOT artifact).

interpret=True for the same reason as crc32.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

FNV_OFFSET = 0x811C9DC5
FNV_PRIME = 0x01000193


def _fnv1a_kernel(keys_ref, len_ref, out_ref):
    """keys_ref: u8[B, K]; len_ref: i32[B]; out_ref: u32[B]."""
    keys = keys_ref[...].astype(jnp.uint32)
    lens = len_ref[...]
    n = keys.shape[0]
    h0 = jnp.full((n,), FNV_OFFSET, dtype=jnp.uint32)

    def body(i, h):
        byte = jax.lax.dynamic_slice_in_dim(keys, i, 1, axis=1)[:, 0]
        nxt = (h ^ byte) * jnp.uint32(FNV_PRIME)  # wrapping u32 multiply
        return jnp.where(i < lens, nxt, h)

    out_ref[...] = jax.lax.fori_loop(0, keys.shape[1], body, h0)


def fnv1a_batch(keys: jax.Array, lengths: jax.Array) -> jax.Array:
    """Batched FNV-1a-32 over padded key rows.

    Args:
      keys:    u8[B, K] key bytes, rows padded past `lengths`.
      lengths: i32[B] valid byte count per row.

    Returns:
      u32[B] FNV-1a-32 hash of each row (bucket = hash % num_buckets, done by
      the caller so the artifact stays independent of table size).
    """
    if keys.ndim != 2:
        raise ValueError(f"keys must be rank-2 (B, K), got shape {keys.shape}")
    if lengths.shape != (keys.shape[0],):
        raise ValueError(
            f"lengths shape {lengths.shape} does not match batch {keys.shape[0]}"
        )
    b = keys.shape[0]
    return pl.pallas_call(
        _fnv1a_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.uint32),
        interpret=True,
    )(keys.astype(jnp.uint8), lengths.astype(jnp.int32))
