"""Pure-jnp (and pure-python) oracles for the L1 kernels.

These are the correctness references: pytest checks kernel == ref == zlib
on swept shapes/lengths/dtypes. Keep them boring and obviously correct.
"""

from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp
import numpy as np

from .crc32 import CRC32_INIT, crc32_table
from .keyhash import FNV_OFFSET, FNV_PRIME


def crc32_ref_jnp(data: jax.Array, lengths: jax.Array) -> jax.Array:
    """Pure-jnp batched CRC32 (no pallas): same algorithm, scan over columns."""
    data = data.astype(jnp.uint32)
    lens = lengths.astype(jnp.int32)
    table = crc32_table()
    crc0 = jnp.full((data.shape[0],), CRC32_INIT, dtype=jnp.uint32)

    def step(crc, col_i):
        col, i = col_i
        idx = (crc ^ col) & jnp.uint32(0xFF)
        nxt = jnp.take(table, idx, axis=0) ^ (crc >> jnp.uint32(8))
        return jnp.where(i < lens, nxt, crc), None

    cols = jnp.swapaxes(data, 0, 1)  # (L, B)
    idxs = jnp.arange(data.shape[1], dtype=jnp.int32)
    crc, _ = jax.lax.scan(step, crc0, (cols, idxs))
    return crc ^ jnp.uint32(CRC32_INIT)


def crc32_ref_py(row: bytes) -> int:
    """Ground truth: zlib's CRC32 (same polynomial / reflection / init)."""
    return zlib.crc32(row) & 0xFFFFFFFF


def fnv1a_ref_jnp(keys: jax.Array, lengths: jax.Array) -> jax.Array:
    keys = keys.astype(jnp.uint32)
    lens = lengths.astype(jnp.int32)
    h0 = jnp.full((keys.shape[0],), FNV_OFFSET, dtype=jnp.uint32)

    def step(h, col_i):
        col, i = col_i
        nxt = (h ^ col) * jnp.uint32(FNV_PRIME)
        return jnp.where(i < lens, nxt, h), None

    cols = jnp.swapaxes(keys, 0, 1)
    idxs = jnp.arange(keys.shape[1], dtype=jnp.int32)
    h, _ = jax.lax.scan(step, h0, (cols, idxs))
    return h


def fnv1a_ref_py(row: bytes) -> int:
    h = FNV_OFFSET
    for b in row:
        h = ((h ^ b) * FNV_PRIME) & 0xFFFFFFFF
    return h


def pad_rows(rows: list[bytes], width: int | None = None) -> tuple[np.ndarray, np.ndarray]:
    """Pack variable-length byte rows into (u8[B, W], i32[B]) for the kernels."""
    if width is None:
        width = max((len(r) for r in rows), default=1) or 1
    out = np.zeros((len(rows), width), dtype=np.uint8)
    lens = np.zeros((len(rows),), dtype=np.int32)
    for i, r in enumerate(rows):
        if len(r) > width:
            raise ValueError(f"row {i} length {len(r)} exceeds width {width}")
        out[i, : len(r)] = np.frombuffer(r, dtype=np.uint8)
        lens[i] = len(r)
    return out, lens
