"""L2: the batched object-integrity pipeline (JAX, build time only).

Erda objects are `[delete-tag | crc32 | key-value]`; the checksum covers the
whole object with the CRC field itself zeroed during computation (the Rust
codec in rust/src/log/object.rs uses the same convention). This module is
what gets AOT-lowered to HLO for the Rust runtime:

  verify_batch : (objects u8[B,L], lengths i32[B], stored u32[B])
                 -> (crc u32[B], valid u32[B])
  bucket_batch : (keys u8[B,K], lengths i32[B]) -> u32[B]

`valid[i]` is 1 iff the object bytes hash to `stored[i]` AND the row is
non-empty (length > 0). The Rust recovery scan feeds each candidate object's
bytes with the CRC field zeroed, its stored checksum, and rolls back hash
entries whose newest version fails verification.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.crc32 import crc32_batch
from .kernels.keyhash import fnv1a_batch


def verify_batch(
    objects: jax.Array,
    lengths: jax.Array,
    stored: jax.Array,
    table: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Compute batch CRC32 and compare against stored checksums.

    Returns (crc u32[B], valid u32[B]); valid is 0/1 as u32 so every output
    is a plain u32 array (keeps the PJRT-side decoding uniform). `table` is
    threaded to the kernel; the AOT entry point takes it as a parameter (see
    kernels/crc32.py for why it cannot be an embedded constant).
    """
    crc = crc32_batch(objects, lengths, table)
    ok = (crc == stored.astype(jnp.uint32)) & (lengths.astype(jnp.int32) > 0)
    return crc, ok.astype(jnp.uint32)


def bucket_batch(keys: jax.Array, lengths: jax.Array) -> jax.Array:
    """Batched FNV-1a-32 key hash (bucket = hash % table_size, caller-side)."""
    return fnv1a_batch(keys, lengths)
