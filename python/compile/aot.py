"""AOT: lower the L2 pipeline to HLO text artifacts for the Rust runtime.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` 0.1.6 crate) rejects (`proto.id() <=
INT_MAX`). The text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Run: `cd python && python -m compile.aot --out ../artifacts`

Outputs one `<name>.hlo.txt` per (function, shape) variant plus
`manifest.txt` with lines:

    <name> <kind> <batch> <width> <n_outputs> <file>

The Rust runtime (rust/src/runtime) parses the manifest and compiles each
artifact once on the PJRT CPU client.
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# (batch, padded-width) variants. Widths cover the object sizes exercised by
# the paper's value sweep (16 B .. 4096 B values + header/key) and the
# recovery scan's segment batches. One executable per static shape.
VERIFY_VARIANTS = [
    (64, 128),
    (64, 512),
    (64, 1024),
    (64, 4352),
    (256, 128),
]
BUCKET_VARIANTS = [
    (64, 64),
    (256, 64),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_verify(batch: int, width: int) -> str:
    import jax.numpy as jnp

    data = jax.ShapeDtypeStruct((batch, width), jnp.uint8)
    lens = jax.ShapeDtypeStruct((batch,), jnp.int32)
    stored = jax.ShapeDtypeStruct((batch,), jnp.uint32)
    # The CRC table is the 4th runtime parameter (cannot be an HLO constant;
    # see kernels/crc32.py).
    table = jax.ShapeDtypeStruct((256,), jnp.uint32)
    return to_hlo_text(jax.jit(model.verify_batch).lower(data, lens, stored, table))


def lower_bucket(batch: int, width: int) -> str:
    import jax.numpy as jnp

    keys = jax.ShapeDtypeStruct((batch, width), jnp.uint8)
    lens = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return to_hlo_text(jax.jit(model.bucket_batch).lower(keys, lens))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", required=True, help="artifacts output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest_lines = []
    for batch, width in VERIFY_VARIANTS:
        name = f"verify_b{batch}_w{width}"
        path = os.path.join(args.out, f"{name}.hlo.txt")
        text = lower_verify(batch, width)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"{name} verify {batch} {width} 2 {name}.hlo.txt")
        print(f"wrote {path} ({len(text)} chars)")
    for batch, width in BUCKET_VARIANTS:
        name = f"bucket_b{batch}_w{width}"
        path = os.path.join(args.out, f"{name}.hlo.txt")
        text = lower_bucket(batch, width)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"{name} bucket {batch} {width} 1 {name}.hlo.txt")
        print(f"wrote {path} ({len(text)} chars)")

    manifest = os.path.join(args.out, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {manifest} ({len(manifest_lines)} artifacts)")


if __name__ == "__main__":
    main()
