"""L2 pipeline tests: verify_batch / bucket_batch semantics + AOT lowering."""

from __future__ import annotations

import numpy as np
import pytest

from compile import aot, model
from compile.kernels.ref import crc32_ref_py, fnv1a_ref_py, pad_rows


def test_verify_batch_flags_corruption():
    rows = [b"object-one", b"object-two-longer", b""]
    data, lens = pad_rows(rows, width=32)
    stored = np.array(
        [crc32_ref_py(rows[0]), crc32_ref_py(rows[1]) ^ 0xDEAD, 0], dtype=np.uint32
    )
    crc, valid = model.verify_batch(data, lens, stored)
    crc, valid = np.asarray(crc), np.asarray(valid)
    assert valid.tolist() == [1, 0, 0]  # ok, corrupted, empty row
    assert crc[0] == stored[0]
    assert crc[1] != stored[1]


def test_verify_batch_all_valid_roundtrip():
    rng = np.random.default_rng(3)
    rows = [rng.integers(0, 256, size=int(rng.integers(1, 100)), dtype=np.uint8).tobytes() for _ in range(16)]
    data, lens = pad_rows(rows, width=128)
    stored = np.array([crc32_ref_py(r) for r in rows], dtype=np.uint32)
    _, valid = model.verify_batch(data, lens, stored)
    assert np.asarray(valid).tolist() == [1] * 16


def test_bucket_batch_matches_py():
    rows = [b"user%d" % i for i in range(32)]
    data, lens = pad_rows(rows, width=64)
    out = np.asarray(model.bucket_batch(data, lens))
    expect = np.array([fnv1a_ref_py(r) for r in rows], dtype=np.uint32)
    np.testing.assert_array_equal(out, expect)


@pytest.mark.parametrize("batch,width", [(8, 64), (64, 128)])
def test_aot_lowering_produces_hlo_text(batch, width):
    text = aot.lower_verify(batch, width)
    assert "HloModule" in text
    assert "ENTRY" in text
    text2 = aot.lower_bucket(batch, 32)
    assert "HloModule" in text2


def test_aot_hlo_is_deterministic():
    a = aot.lower_bucket(8, 16)
    b = aot.lower_bucket(8, 16)
    assert a == b
