"""AOT pipeline tests: manifest generation, artifact shape contracts, and
the table-as-parameter rule the Rust runtime depends on."""

from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import pytest

from compile import aot, model
from compile.kernels.crc32 import crc32_batch, crc32_table
from compile.kernels.ref import crc32_ref_py, pad_rows


def test_verify_entry_takes_table_parameter():
    # xla_extension 0.5.1 corrupts large dense constants across the HLO-text
    # round trip (gather degenerates to iota), so the lowered entry MUST
    # take the CRC table as its 4th parameter.
    text = aot.lower_verify(8, 64)
    header = text.splitlines()[0]
    assert "u32[256]" in header, f"table parameter missing from entry: {header}"
    assert "u8[8,64]" in header
    assert "(u32[8]{0}, u32[8]{0})" in header or "u32[8]" in header


def test_bucket_entry_shapes():
    text = aot.lower_bucket(16, 32)
    header = text.splitlines()[0]
    assert "u8[16,32]" in header
    assert "s32[16]" in header


def test_explicit_table_matches_default():
    rows = [b"123456789", b"x" * 50]
    data, lens = pad_rows(rows, width=64)
    a = np.asarray(crc32_batch(data, lens))
    b = np.asarray(crc32_batch(data, lens, crc32_table()))
    np.testing.assert_array_equal(a, b)
    assert a[0] == crc32_ref_py(rows[0])


def test_verify_batch_with_table_param():
    rows = [b"object-a", b"object-bb"]
    data, lens = pad_rows(rows, width=32)
    stored = np.array([crc32_ref_py(r) for r in rows], dtype=np.uint32)
    _, valid = model.verify_batch(data, lens, stored, crc32_table())
    assert np.asarray(valid).tolist() == [1, 1]


def test_aot_main_writes_manifest(tmp_path):
    # Full CLI run into a temp dir (slow-ish: lowers every variant once).
    out = tmp_path / "arts"
    proc = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out)],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr
    manifest = (out / "manifest.txt").read_text().strip().splitlines()
    assert len(manifest) == len(aot.VERIFY_VARIANTS) + len(aot.BUCKET_VARIANTS)
    for line in manifest:
        name, kind, batch, width, n_out, fname = line.split()
        assert kind in ("verify", "bucket")
        assert int(batch) > 0 and int(width) > 0
        assert int(n_out) == (2 if kind == "verify" else 1)
        text = (out / fname).read_text()
        assert text.startswith("HloModule"), f"{fname} is not HLO text"


@pytest.mark.parametrize("batch,width", [(1, 8), (7, 33), (64, 4352)])
def test_lowering_odd_shapes(batch, width):
    # Non-power-of-two shapes must lower cleanly too (the runtime picks the
    # smallest artifact that fits, but lowering itself is shape-agnostic).
    text = aot.lower_verify(batch, width)
    assert "HloModule" in text
