"""Kernel vs oracle correctness: the CORE build-time signal.

Pallas kernel == pure-jnp reference == zlib/python ground truth, swept over
shapes, lengths and content patterns (hypothesis-style randomized sweeps with
fixed seeds — the `hypothesis` package is not installed on this image, so we
sweep explicitly over seeded random cases).
"""

from __future__ import annotations

import zlib

import numpy as np
import pytest

from compile.kernels.crc32 import crc32_batch
from compile.kernels.keyhash import fnv1a_batch
from compile.kernels.ref import (
    crc32_ref_jnp,
    crc32_ref_py,
    fnv1a_ref_jnp,
    fnv1a_ref_py,
    pad_rows,
)

RNG_SEEDS = [0, 1, 7, 42, 1337]


def random_rows(seed: int, batch: int, max_len: int) -> list[bytes]:
    rng = np.random.default_rng(seed)
    rows = []
    for _ in range(batch):
        n = int(rng.integers(0, max_len + 1))
        rows.append(rng.integers(0, 256, size=n, dtype=np.uint8).tobytes())
    return rows


# ---------------------------------------------------------------- CRC32


def test_crc32_known_vectors():
    # Classic check value: CRC32("123456789") == 0xCBF43926.
    data, lens = pad_rows([b"123456789", b"", b"\x00" * 32, b"a"], width=64)
    out = np.asarray(crc32_batch(data, lens))
    assert out[0] == 0xCBF43926
    assert out[1] == 0  # CRC of empty string
    assert out[2] == zlib.crc32(b"\x00" * 32) & 0xFFFFFFFF
    assert out[3] == zlib.crc32(b"a") & 0xFFFFFFFF


@pytest.mark.parametrize("seed", RNG_SEEDS)
@pytest.mark.parametrize("batch,max_len", [(1, 1), (3, 17), (8, 64), (64, 128), (16, 300)])
def test_crc32_kernel_vs_zlib(seed, batch, max_len):
    rows = random_rows(seed * 1000 + batch, batch, max_len)
    data, lens = pad_rows(rows, width=max_len or 1)
    out = np.asarray(crc32_batch(data, lens))
    expect = np.array([crc32_ref_py(r) for r in rows], dtype=np.uint32)
    np.testing.assert_array_equal(out, expect)


@pytest.mark.parametrize("seed", RNG_SEEDS)
def test_crc32_kernel_vs_jnp_ref(seed):
    rows = random_rows(seed, 32, 96)
    data, lens = pad_rows(rows, width=96)
    np.testing.assert_array_equal(
        np.asarray(crc32_batch(data, lens)), np.asarray(crc32_ref_jnp(data, lens))
    )


def test_crc32_padding_is_ignored():
    # Same logical rows, different garbage padding -> same CRC.
    rows = [b"hello world", b"xyz"]
    a, lens = pad_rows(rows, width=32)
    b = a.copy()
    b[0, 11:] = 0xAB
    b[1, 3:] = 0xCD
    np.testing.assert_array_equal(
        np.asarray(crc32_batch(a, lens)), np.asarray(crc32_batch(b, lens))
    )


def test_crc32_shape_validation():
    data, lens = pad_rows([b"ok"], width=8)
    with pytest.raises(ValueError):
        crc32_batch(data[0], lens)  # rank-1 data
    with pytest.raises(ValueError):
        crc32_batch(data, np.zeros((2,), dtype=np.int32))  # batch mismatch


def test_crc32_detects_single_bit_flip():
    rows = [bytes(range(64))]
    data, lens = pad_rows(rows, width=64)
    base = int(np.asarray(crc32_batch(data, lens))[0])
    for byte_idx in [0, 7, 31, 63]:
        flipped = data.copy()
        flipped[0, byte_idx] ^= 0x01
        got = int(np.asarray(crc32_batch(flipped, lens))[0])
        assert got != base, f"bit flip at byte {byte_idx} not detected"


# ---------------------------------------------------------------- FNV-1a


def test_fnv1a_known_vectors():
    # Standard FNV-1a-32 test vectors.
    data, lens = pad_rows([b"", b"a", b"foobar"], width=16)
    out = np.asarray(fnv1a_batch(data, lens))
    assert out[0] == 0x811C9DC5
    assert out[1] == 0xE40C292C
    assert out[2] == 0xBF9CF968


@pytest.mark.parametrize("seed", RNG_SEEDS)
@pytest.mark.parametrize("batch,max_len", [(1, 1), (8, 24), (64, 64)])
def test_fnv1a_kernel_vs_py(seed, batch, max_len):
    rows = random_rows(seed * 31 + batch, batch, max_len)
    data, lens = pad_rows(rows, width=max_len or 1)
    out = np.asarray(fnv1a_batch(data, lens))
    expect = np.array([fnv1a_ref_py(r) for r in rows], dtype=np.uint32)
    np.testing.assert_array_equal(out, expect)


@pytest.mark.parametrize("seed", RNG_SEEDS)
def test_fnv1a_kernel_vs_jnp_ref(seed):
    rows = random_rows(seed + 99, 16, 48)
    data, lens = pad_rows(rows, width=48)
    np.testing.assert_array_equal(
        np.asarray(fnv1a_batch(data, lens)), np.asarray(fnv1a_ref_jnp(data, lens))
    )


def test_fnv1a_shape_validation():
    data, lens = pad_rows([b"k1"], width=8)
    with pytest.raises(ValueError):
        fnv1a_batch(data[0], lens)
    with pytest.raises(ValueError):
        fnv1a_batch(data, np.zeros((3,), dtype=np.int32))
